//! Automated synthesis workflow orchestrator (paper §4.2, Fig. 4a).
//!
//! Ties the full CNN2Gate pipeline together for one model + target:
//! flow extraction → (optional) quantization application → DSE (RL or
//! BF) → resource estimate at H_best → synthesis-time model → latency
//! simulation. Emulation mode instead routes execution through the PJRT
//! runtime (see [`crate::coordinator`]).
//!
//! "CNN2Gate is also capable of building and running the CNN model in
//! both emulation and full flow mode."

use anyhow::{anyhow, Result};

use crate::dse::{brute, eval, rl, DseResult, Evaluator, Fidelity, RlConfig};
use crate::estimator::{synthesis_minutes, Device, ResourceEstimate, Thresholds};
use crate::ir::{ComputationFlow, Graph};
use crate::quant::{self, QuantReport, QuantSpec};
use crate::sim::{NetworkStepReport, SimReport};

/// Which explorer drives the fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Explorer {
    BruteForce,
    Reinforcement,
}

/// Build mode (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// CPU verification path (PJRT, seconds to build).
    Emulation,
    /// Full FPGA flow (simulated synthesis, hours modeled).
    FullFlow,
}

/// Everything the synthesis flow produced for one target.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub model: String,
    pub device: &'static str,
    pub explorer: Explorer,
    pub dse: DseResult,
    /// Present when the design fits.
    pub estimate: Option<ResourceEstimate>,
    pub synthesis_minutes: Option<f64>,
    pub sim: Option<SimReport>,
    /// Per-layer cycle-accurate stall/backpressure census of the chosen
    /// design (present when the flow ran at
    /// [`Fidelity::SteppedFullNetwork`] and the design fits).
    pub stepped_network: Option<NetworkStepReport>,
    pub quant: Option<QuantReport>,
}

impl SynthReport {
    pub fn fits(&self) -> bool {
        self.estimate.is_some()
    }

    pub fn option(&self) -> Option<(usize, usize)> {
        self.dse.best
    }

    pub fn latency_ms(&self) -> Option<f64> {
        self.sim.as_ref().map(|s| s.total_millis)
    }
}

/// Run the flow for `graph` on `device`.
///
/// `quant_spec` is the user-given post-training quantization; pass `None`
/// to skip the application step (models without resident weights).
pub fn run(
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
) -> Result<SynthReport> {
    run_with(eval::global(), graph, device, explorer, thresholds, quant_spec)
}

/// Same flow through a caller-provided evaluator — what the fleet/sweep
/// fan-outs and the `--cache-file` CLI path use, so every explorer in a
/// run shares one (possibly disk-seeded) estimator memo.
pub fn run_with(
    evaluator: &Evaluator,
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
) -> Result<SynthReport> {
    run_with_fidelity(
        evaluator,
        graph,
        device,
        explorer,
        thresholds,
        quant_spec,
        Fidelity::Analytical,
    )
}

/// The full flow at an explicit [`Fidelity`]: stepped modes score every
/// explored candidate through the cycle-accurate simulator, and
/// `SteppedFullNetwork` surfaces the chosen design's per-layer
/// stall/backpressure census on the report (the `synth --report` path).
/// The chosen design itself is fidelity-independent.
pub fn run_with_fidelity(
    evaluator: &Evaluator,
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
    fidelity: Fidelity,
) -> Result<SynthReport> {
    let flow = ComputationFlow::extract(graph).map_err(|e| anyhow!("flow extraction: {e}"))?;

    let quant = match quant_spec {
        Some(spec) => Some(quant::apply(graph, spec).map_err(|e| anyhow!("quantization: {e}"))?),
        None => None,
    };

    let dse = match explorer {
        Explorer::BruteForce => {
            brute::explore_with_fidelity(evaluator, &flow, device, thresholds, fidelity)
        }
        Explorer::Reinforcement => rl::explore_with_fidelity(
            evaluator,
            &flow,
            device,
            thresholds,
            RlConfig::default(),
            fidelity,
        ),
    };

    let (estimate, synth_min, sim, stepped_network) = match (dse.best, &dse.best_estimate) {
        (Some((ni, nl)), Some(est)) => {
            let minutes = synthesis_minutes(est, device);
            // the chosen option was already scored during exploration —
            // pull its latency report from the shared memo (bit-identical
            // to simulate(): Evaluation.latency IS simulate_with_estimate
            // over the same single estimator call) instead of re-deriving
            // it, so warm cache-file runs recompute nothing
            let (chosen, _) = evaluator.evaluate(&flow, device, ni, nl, fidelity);
            (
                Some(est.clone()),
                Some(minutes),
                Some(chosen.latency.clone()),
                chosen.stepped_network.clone(),
            )
        }
        _ => (None, None, None, None),
    };

    Ok(SynthReport {
        model: graph.name.clone(),
        device: device.name,
        explorer,
        dse,
        estimate,
        synthesis_minutes: synth_min,
        sim,
        stepped_network,
        quant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;

    #[test]
    fn full_flow_alexnet_arria10() {
        let g = zoo::build("alexnet", true).unwrap();
        let spec = QuantSpec::default();
        let rep = run(
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            Some(&spec),
        )
        .unwrap();
        assert!(rep.fits());
        assert_eq!(rep.option(), Some((16, 32)));
        // Table 2: 8.5 hrs synthesis
        let synth = rep.synthesis_minutes.unwrap();
        assert!((synth - 510.0).abs() < 40.0, "{synth}");
        // Table 1: 18 ms
        let lat = rep.latency_ms().unwrap();
        assert!((lat - 18.24).abs() < 2.0, "{lat}");
        assert!(rep.quant.is_some());
    }

    #[test]
    fn rl_flow_matches_bf_choice() {
        let g = zoo::build("alexnet", false).unwrap();
        let bf = run(&g, &CYCLONE_V_5CSEMA5, Explorer::BruteForce, Thresholds::default(), None)
            .unwrap();
        let rl = run(
            &g,
            &CYCLONE_V_5CSEMA5,
            Explorer::Reinforcement,
            Thresholds::default(),
            None,
        )
        .unwrap();
        assert_eq!(bf.option(), rl.option());
        assert!(rl.dse.queries < bf.dse.queries);
    }

    #[test]
    fn no_fit_report_is_complete() {
        let g = zoo::build("alexnet", false).unwrap();
        let rep = run(
            &g,
            &CYCLONE_V_5CSEMA4,
            Explorer::BruteForce,
            Thresholds::default(),
            None,
        )
        .unwrap();
        assert!(!rep.fits());
        assert_eq!(rep.latency_ms(), None);
        assert_eq!(rep.synthesis_minutes, None);
    }

    #[test]
    fn stepped_full_network_flow_surfaces_the_census() {
        use crate::dse::Evaluator;
        let g = zoo::build("alexnet", false).unwrap();
        let ev = Evaluator::new(4);
        let rep = run_with_fidelity(
            &ev,
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            None,
            Fidelity::SteppedFullNetwork,
        )
        .unwrap();
        // same design as the analytical flow...
        let base = run(&g, &ARRIA_10_GX1150, Explorer::BruteForce, Thresholds::default(), None)
            .unwrap();
        assert_eq!(rep.option(), base.option());
        assert_eq!(rep.dse.trace, base.dse.trace);
        assert_eq!(rep.latency_ms(), base.latency_ms());
        // ...plus a per-round census aligned with the latency breakdown
        let net = rep.stepped_network.as_ref().expect("census on the report");
        assert_eq!(net.layers.len(), rep.sim.as_ref().unwrap().layers.len());
        assert!(net.total_cycles() > 0);
        assert!(base.stepped_network.is_none(), "analytical flow carries none");
    }

    #[test]
    fn quantization_requires_weights() {
        let g = zoo::build("alexnet", false).unwrap(); // no weights
        let spec = QuantSpec::default();
        let err = run(
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            Some(&spec),
        )
        .unwrap_err();
        assert!(err.to_string().contains("quantization"));
    }
}
