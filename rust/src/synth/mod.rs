//! Automated synthesis workflow orchestrator (paper §4.2, Fig. 4a).
//!
//! Ties the full CNN2Gate pipeline together for one model + target:
//! flow extraction → (optional) quantization application → DSE (RL or
//! BF) → resource estimate at H_best → synthesis-time model → latency
//! simulation. Emulation mode instead routes execution through the PJRT
//! runtime (see [`crate::coordinator`]).
//!
//! "CNN2Gate is also capable of building and running the CNN model in
//! both emulation and full flow mode."
//!
//! The flow itself now lives in [`crate::session`]: a 1×1
//! [`CompileJob`](crate::session::CompileJob) run through
//! [`Session::run`](crate::session::Session::run) is exactly this
//! module's old `run` ladder. The free functions below survive as
//! deprecated shims over the same engine — bit-identical by
//! construction, and pinned so by the shim tests — so existing callers
//! keep working while new code goes through the session.

use anyhow::Result;

use crate::dse::{eval, DseResult, Evaluator, Fidelity};
use crate::estimator::{Device, ResourceEstimate, Thresholds};
use crate::ir::Graph;
use crate::quant::{QuantReport, QuantSpec};
use crate::sim::{NetworkStepReport, SimReport};

/// Which explorer drives the fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Explorer {
    BruteForce,
    Reinforcement,
}

/// Build mode (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// CPU verification path (PJRT, seconds to build).
    Emulation,
    /// Full FPGA flow (simulated synthesis, hours modeled).
    FullFlow,
}

/// Everything the synthesis flow produced for one target.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub model: String,
    pub device: &'static str,
    pub explorer: Explorer,
    pub dse: DseResult,
    /// Present when the design fits.
    pub estimate: Option<ResourceEstimate>,
    pub synthesis_minutes: Option<f64>,
    pub sim: Option<SimReport>,
    /// Per-layer cycle-accurate stall/backpressure census of the chosen
    /// design (present when the flow ran at
    /// [`Fidelity::SteppedFullNetwork`] and the design fits).
    pub stepped_network: Option<NetworkStepReport>,
    pub quant: Option<QuantReport>,
}

impl SynthReport {
    pub fn fits(&self) -> bool {
        self.estimate.is_some()
    }

    pub fn option(&self) -> Option<(usize, usize)> {
        self.dse.best
    }

    pub fn latency_ms(&self) -> Option<f64> {
        self.sim.as_ref().map(|s| s.total_millis)
    }
}

/// One (model, device) pair through the session engine — the shared
/// body of every shim below.
fn one_pair(
    evaluator: &Evaluator,
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
    fidelity: Fidelity,
) -> Result<SynthReport> {
    let run = crate::session::execute(
        evaluator,
        std::slice::from_ref(graph),
        &[device],
        explorer,
        thresholds,
        quant_spec,
        fidelity,
    )?;
    Ok(run
        .entries
        .into_iter()
        .next()
        .expect("a 1x1 job yields exactly one report"))
}

/// Run the flow for `graph` on `device`.
///
/// `quant_spec` is the user-given post-training quantization; pass `None`
/// to skip the application step (models without resident weights).
#[deprecated(note = "use a 1x1 cnn2gate::session::CompileJob with Session::run")]
pub fn run(
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
) -> Result<SynthReport> {
    one_pair(
        eval::global(),
        graph,
        device,
        explorer,
        thresholds,
        quant_spec,
        Fidelity::Analytical,
    )
}

/// Same flow through a caller-provided evaluator — what the fleet/sweep
/// fan-outs and the `--cache-file` CLI path used before sessions owned
/// the evaluator.
#[deprecated(note = "use cnn2gate::session::Session, which owns the evaluator")]
pub fn run_with(
    evaluator: &Evaluator,
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
) -> Result<SynthReport> {
    one_pair(
        evaluator,
        graph,
        device,
        explorer,
        thresholds,
        quant_spec,
        Fidelity::Analytical,
    )
}

/// The full flow at an explicit [`Fidelity`]: stepped modes score every
/// explored candidate through the cycle-accurate simulator, and
/// `SteppedFullNetwork` surfaces the chosen design's per-layer
/// stall/backpressure census on the report (the `synth --report` path).
/// The chosen design itself is fidelity-independent.
#[deprecated(note = "set the fidelity on cnn2gate::session::SessionBuilder instead")]
pub fn run_with_fidelity(
    evaluator: &Evaluator,
    graph: &Graph,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant_spec: Option<&QuantSpec>,
    fidelity: Fidelity,
) -> Result<SynthReport> {
    one_pair(
        evaluator, graph, device, explorer, thresholds, quant_spec, fidelity,
    )
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims are exactly what these tests pin

    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;

    #[test]
    fn full_flow_alexnet_arria10() {
        let g = zoo::build("alexnet", true).unwrap();
        let spec = QuantSpec::default();
        let rep = run(
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            Some(&spec),
        )
        .unwrap();
        assert!(rep.fits());
        assert_eq!(rep.option(), Some((16, 32)));
        // Table 2: 8.5 hrs synthesis
        let synth = rep.synthesis_minutes.unwrap();
        assert!((synth - 510.0).abs() < 40.0, "{synth}");
        // Table 1: 18 ms
        let lat = rep.latency_ms().unwrap();
        assert!((lat - 18.24).abs() < 2.0, "{lat}");
        assert!(rep.quant.is_some());
    }

    #[test]
    fn rl_flow_matches_bf_choice() {
        let g = zoo::build("alexnet", false).unwrap();
        let bf = run(&g, &CYCLONE_V_5CSEMA5, Explorer::BruteForce, Thresholds::default(), None)
            .unwrap();
        let rl = run(
            &g,
            &CYCLONE_V_5CSEMA5,
            Explorer::Reinforcement,
            Thresholds::default(),
            None,
        )
        .unwrap();
        assert_eq!(bf.option(), rl.option());
        assert!(rl.dse.queries < bf.dse.queries);
    }

    #[test]
    fn no_fit_report_is_complete() {
        let g = zoo::build("alexnet", false).unwrap();
        let rep = run(
            &g,
            &CYCLONE_V_5CSEMA4,
            Explorer::BruteForce,
            Thresholds::default(),
            None,
        )
        .unwrap();
        assert!(!rep.fits());
        assert_eq!(rep.latency_ms(), None);
        assert_eq!(rep.synthesis_minutes, None);
    }

    #[test]
    fn stepped_full_network_flow_surfaces_the_census() {
        use crate::dse::Evaluator;
        let g = zoo::build("alexnet", false).unwrap();
        let ev = Evaluator::new(4);
        let rep = run_with_fidelity(
            &ev,
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            None,
            Fidelity::SteppedFullNetwork,
        )
        .unwrap();
        // same design as the analytical flow...
        let base = run(&g, &ARRIA_10_GX1150, Explorer::BruteForce, Thresholds::default(), None)
            .unwrap();
        assert_eq!(rep.option(), base.option());
        assert_eq!(rep.dse.trace, base.dse.trace);
        assert_eq!(rep.latency_ms(), base.latency_ms());
        // ...plus a per-round census aligned with the latency breakdown
        let net = rep.stepped_network.as_ref().expect("census on the report");
        assert_eq!(net.layers.len(), rep.sim.as_ref().unwrap().layers.len());
        assert!(net.total_cycles() > 0);
        assert!(base.stepped_network.is_none(), "analytical flow carries none");
    }

    #[test]
    fn quantization_requires_weights() {
        let g = zoo::build("alexnet", false).unwrap(); // no weights
        let spec = QuantSpec::default();
        let err = run(
            &g,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            Thresholds::default(),
            Some(&spec),
        )
        .unwrap_err();
        assert!(err.to_string().contains("quantization"));
    }
}
