//! Automated synthesis workflow orchestrator (paper §4.2, Fig. 4a).
//!
//! Ties the full CNN2Gate pipeline together for one model + target:
//! flow extraction → (optional) quantization application → DSE (RL or
//! BF) → resource estimate at H_best → synthesis-time model → latency
//! simulation → (optional) per-layer specialization. Emulation mode
//! instead routes execution through the PJRT runtime (see
//! [`crate::coordinator`]).
//!
//! "CNN2Gate is also capable of building and running the CNN model in
//! both emulation and full flow mode."
//!
//! The flow itself lives in [`crate::session`]: a 1×1
//! [`CompileJob`](crate::session::CompileJob) run through
//! [`Session::run`](crate::session::Session::run) is this module's old
//! `run` ladder. The deprecated free-function shims that used to live
//! here (`run`, `run_with`, `run_with_fidelity`) were removed once
//! nothing cited them; `rust/tests/session.rs` now pins
//! Session-vs-Session determinism instead of shim identity. This module
//! keeps the report types the session produces.

use crate::dse::{DseResult, SpecializationReport, ThroughputChoice};
use crate::estimator::ResourceEstimate;
use crate::quant::QuantReport;
use crate::sim::{NetworkStepReport, SimReport};

/// Which explorer drives the fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Explorer {
    BruteForce,
    Reinforcement,
}

/// Build mode (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// CPU verification path (PJRT, seconds to build).
    Emulation,
    /// Full FPGA flow (simulated synthesis, hours modeled).
    FullFlow,
}

/// Everything the synthesis flow produced for one target.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub model: String,
    pub device: &'static str,
    pub explorer: Explorer,
    /// Batch size the reported design was evaluated at (1 for the
    /// classic single-frame flow; the chosen B when the job ran the
    /// throughput co-optimization).
    pub batch: usize,
    /// Full (N_i, N_l, B) co-optimization sweep (present when the job
    /// asked for throughput mode — `--batch`/`--latency-slo`).
    pub throughput: Option<ThroughputChoice>,
    pub dse: DseResult,
    /// Present when the design fits.
    pub estimate: Option<ResourceEstimate>,
    pub synthesis_minutes: Option<f64>,
    pub sim: Option<SimReport>,
    /// Per-layer cycle-accurate stall/backpressure census of the chosen
    /// design (present when the flow ran at
    /// [`Fidelity::SteppedFullNetwork`](crate::dse::Fidelity) and the
    /// design fits).
    pub stepped_network: Option<NetworkStepReport>,
    /// Per-layer (N_i, N_l) + weight-schedule specialization of the
    /// chosen design (present when the job asked for it — `synth
    /// --specialize` — the flow ran at stepped-full fidelity, and the
    /// design fits).
    pub specialization: Option<SpecializationReport>,
    /// Producer round indices per fused round — the DAG wiring of
    /// branched (residual/separable) models. `None` on linear chains,
    /// whose wiring is implied (round i reads round i-1), so chain-era
    /// reports and documents are unchanged.
    pub round_producers: Option<Vec<Vec<usize>>>,
    pub quant: Option<QuantReport>,
}

impl SynthReport {
    pub fn fits(&self) -> bool {
        self.estimate.is_some()
    }

    pub fn option(&self) -> Option<(usize, usize)> {
        self.dse.best
    }

    pub fn latency_ms(&self) -> Option<f64> {
        self.sim.as_ref().map(|s| s.total_millis)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Fidelity;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::estimator::Thresholds;
    use crate::onnx::zoo;
    use crate::quant::QuantSpec;
    use crate::session::{CompileJob, Session};

    /// 1×1 session run — the flow every test here exercises.
    fn run_one(
        model: &str,
        with_weights: bool,
        device: &'static crate::estimator::Device,
        explorer: Explorer,
        quantize: bool,
        fidelity: Fidelity,
        specialize: bool,
    ) -> SynthReport {
        let session = Session::builder()
            .threads(4)
            .thresholds(Thresholds::default())
            .fidelity(fidelity)
            .build();
        let mut builder = CompileJob::builder()
            .model(zoo::build(model, with_weights).unwrap())
            .device(device)
            .explorer(explorer);
        if quantize {
            builder = builder.quantize(QuantSpec::default());
        }
        if specialize {
            builder = builder.specialize();
        }
        session.run(&builder.build().unwrap()).unwrap().into_synth_report().unwrap()
    }

    #[test]
    fn full_flow_alexnet_arria10() {
        let rep = run_one(
            "alexnet",
            true,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            true,
            Fidelity::Analytical,
            false,
        );
        assert!(rep.fits());
        assert_eq!(rep.option(), Some((16, 32)));
        // Table 2: 8.5 hrs synthesis
        let synth = rep.synthesis_minutes.unwrap();
        assert!((synth - 510.0).abs() < 40.0, "{synth}");
        // Table 1: 18 ms
        let lat = rep.latency_ms().unwrap();
        assert!((lat - 18.24).abs() < 2.0, "{lat}");
        assert!(rep.quant.is_some());
        assert!(rep.specialization.is_none(), "not requested");
    }

    #[test]
    fn rl_flow_matches_bf_choice() {
        let bf = run_one(
            "alexnet",
            false,
            &CYCLONE_V_5CSEMA5,
            Explorer::BruteForce,
            false,
            Fidelity::Analytical,
            false,
        );
        let rl = run_one(
            "alexnet",
            false,
            &CYCLONE_V_5CSEMA5,
            Explorer::Reinforcement,
            false,
            Fidelity::Analytical,
            false,
        );
        assert_eq!(bf.option(), rl.option());
        assert!(rl.dse.queries < bf.dse.queries);
    }

    #[test]
    fn no_fit_report_is_complete() {
        let rep = run_one(
            "alexnet",
            false,
            &CYCLONE_V_5CSEMA4,
            Explorer::BruteForce,
            false,
            Fidelity::SteppedFullNetwork,
            true,
        );
        assert!(!rep.fits());
        assert_eq!(rep.latency_ms(), None);
        assert_eq!(rep.synthesis_minutes, None);
        assert!(rep.stepped_network.is_none());
        assert!(rep.specialization.is_none(), "nothing fits, nothing to specialize");
    }

    #[test]
    fn stepped_full_network_flow_surfaces_the_census() {
        let rep = run_one(
            "alexnet",
            false,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            false,
            Fidelity::SteppedFullNetwork,
            false,
        );
        // same design as the analytical flow...
        let base = run_one(
            "alexnet",
            false,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            false,
            Fidelity::Analytical,
            false,
        );
        assert_eq!(rep.option(), base.option());
        assert_eq!(rep.dse.trace, base.dse.trace);
        assert_eq!(rep.latency_ms(), base.latency_ms());
        // ...plus a per-round census aligned with the latency breakdown
        let net = rep.stepped_network.as_ref().expect("census on the report");
        assert_eq!(net.layers.len(), rep.sim.as_ref().unwrap().layers.len());
        assert!(net.total_cycles() > 0);
        assert!(base.stepped_network.is_none(), "analytical flow carries none");
    }

    #[test]
    fn specialized_flow_carries_the_specialization_report() {
        let rep = run_one(
            "alexnet",
            false,
            &ARRIA_10_GX1150,
            Explorer::BruteForce,
            false,
            Fidelity::SteppedFullNetwork,
            true,
        );
        let spec = rep.specialization.as_ref().expect("specialization report");
        assert_eq!(spec.uniform, rep.option().unwrap());
        assert_eq!(spec.layers.len(), rep.sim.as_ref().unwrap().layers.len());
        // the acceptance relation, end to end through the session
        assert!(
            spec.specialized_total_cycles() as f64 <= 0.95 * spec.uniform_total_cycles() as f64
        );
        // the pass consumed exactly the report's own census
        assert_eq!(
            spec.uniform_total_cycles(),
            rep.stepped_network.as_ref().unwrap().total_cycles()
        );
    }

    #[test]
    fn quantization_requires_weights() {
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap()) // no weights
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .quantize(QuantSpec::default())
            .build()
            .unwrap();
        let err = session.run(&job).unwrap_err();
        assert!(err.to_string().contains("quantization"));
    }
}
