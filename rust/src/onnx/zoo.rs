//! Model zoo: programmatic builders for the topologies the paper
//! evaluates (AlexNet, VGG-16) plus LeNet-5 and a tiny test CNN, and
//! the branch-family additions: ResNet-18 (residual basic blocks),
//! MobileNetV1 (depthwise-separable stacks) and `tinyres` (a small
//! residual+depthwise net for fast tests).
//!
//! The linear models mirror `python/compile/model.py` layer-for-layer;
//! the pytest / cargo integration tests cross-check both sides against
//! the ONNX-subset JSON emitted by `make artifacts`. The branched
//! models are built on [`BranchBuilder`], which emits the same node
//! idiom (`l{li}_w`/`l{li}_b` initializers, `t{n}` tensors, biases on
//! every parameterized layer, no batch-norm — folded into conv params,
//! as a deployment-ready graph would carry).

use std::collections::HashMap;

use crate::ir::{ConvAttrs, DType, Graph, Initializer, Node, Op, PoolAttrs, TensorInfo};
use crate::util::rng::Rng;

/// Internal layer description used by the builders.
enum L {
    Conv {
        cout: usize,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
    },
    Pool {
        k: usize,
        s: usize,
    },
    Fc {
        n: usize,
        relu: bool,
    },
}

fn conv(cout: usize, k: usize, s: usize, p: usize) -> L {
    L::Conv {
        cout,
        k,
        s,
        p,
        relu: true,
    }
}

fn pool(k: usize, s: usize) -> L {
    L::Pool { k, s }
}

fn fc(n: usize) -> L {
    L::Fc { n, relu: true }
}

fn fc_last(n: usize) -> L {
    L::Fc { n, relu: false }
}

fn spec(name: &str) -> Option<(Vec<usize>, Vec<L>)> {
    let layers = match name {
        "tiny" => (
            vec![1, 8, 8],
            vec![conv(4, 3, 1, 1), pool(2, 2), fc_last(10)],
        ),
        "lenet5" => (
            vec![1, 28, 28],
            vec![
                conv(6, 5, 1, 2),
                pool(2, 2),
                conv(16, 5, 1, 0),
                pool(2, 2),
                fc(120),
                fc(84),
                fc_last(10),
            ],
        ),
        "alexnet" => (
            vec![3, 224, 224],
            vec![
                conv(64, 11, 4, 2),
                pool(3, 2),
                conv(192, 5, 1, 2),
                pool(3, 2),
                conv(384, 3, 1, 1),
                conv(256, 3, 1, 1),
                conv(256, 3, 1, 1),
                pool(3, 2),
                fc(4096),
                fc(4096),
                fc_last(1000),
            ],
        ),
        "vgg16" => {
            let mut ls = Vec::new();
            for (reps, cout) in [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)] {
                for _ in 0..reps {
                    ls.push(conv(cout, 3, 1, 1));
                }
                ls.push(pool(2, 2));
            }
            ls.push(fc(4096));
            ls.push(fc(4096));
            ls.push(fc_last(1000));
            (vec![3, 224, 224], ls)
        }
        _ => return None,
    };
    Some(layers)
}

/// Names available in the zoo.
pub fn names() -> &'static [&'static str] {
    &[
        "tiny",
        "lenet5",
        "alexnet",
        "vgg16",
        "resnet18",
        "mobilenetv1",
        "tinyres",
    ]
}

/// Build a zoo model. `with_weights` materializes He-initialized
/// synthetic parameters (deterministic seed per model); without it the
/// initializers carry shape/dtype only (ONNX external-data style).
pub fn build(name: &str, with_weights: bool) -> Option<Graph> {
    match name {
        "resnet18" => build_resnet18(with_weights),
        "mobilenetv1" => build_mobilenetv1(with_weights),
        "tinyres" => build_tinyres(with_weights),
        _ => build_linear(name, with_weights),
    }
}

fn build_linear(name: &str, with_weights: bool) -> Option<Graph> {
    let (input_shape, layers) = spec(name)?;
    let mut rng = Rng::new(0xC44_2_6A7E ^ name.len() as u64);
    let mut nodes = Vec::new();
    let mut initializers = HashMap::new();
    let mut tname = "input".to_string();
    let mut t = 0usize;
    let mut shape = input_shape.clone();
    let fresh = |t: &mut usize| {
        let n = format!("t{t}");
        *t += 1;
        n
    };
    for (li, layer) in layers.iter().enumerate() {
        match layer {
            L::Conv { cout, k, s, p, relu } => {
                let cin = shape[0];
                let (wname, bname) = (format!("l{li}_w"), format!("l{li}_b"));
                let wlen = cout * cin * k * k;
                initializers.insert(
                    wname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*cout, cin, *k, *k],
                            dtype: DType::F32,
                        },
                        data: with_weights.then(|| rng.he_weights(wlen, cin * k * k)),
                    },
                );
                initializers.insert(
                    bname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*cout],
                            dtype: DType::F32,
                        },
                        data: with_weights
                            .then(|| (0..*cout).map(|_| (rng.normal() * 0.05) as f32).collect()),
                    },
                );
                let attrs = ConvAttrs {
                    kernel: [*k, *k],
                    strides: [*s, *s],
                    pads: [*p, *p],
                    dilations: [1, 1],
                    groups: 1,
                };
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::Conv(attrs),
                    inputs: vec![tname.clone(), wname, bname],
                    outputs: vec![out.clone()],
                });
                let (oh, ow) = attrs.out_hw(shape[1], shape[2])?;
                shape = vec![*cout, oh, ow];
                tname = out;
                if *relu {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Relu,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                }
            }
            L::Pool { k, s } => {
                let attrs = PoolAttrs {
                    kernel: [*k, *k],
                    strides: [*s, *s],
                    pads: [0, 0],
                    dilations: [1, 1],
                };
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::MaxPool(attrs),
                    inputs: vec![tname.clone()],
                    outputs: vec![out.clone()],
                });
                let (oh, ow) = attrs.out_hw(shape[1], shape[2])?;
                shape = vec![shape[0], oh, ow];
                tname = out;
            }
            L::Fc { n, relu } => {
                if shape.len() > 1 {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Flatten,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                    shape = vec![shape.iter().product()];
                }
                let kdim = shape[0];
                let (wname, bname) = (format!("l{li}_w"), format!("l{li}_b"));
                initializers.insert(
                    wname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*n, kdim],
                            dtype: DType::F32,
                        },
                        data: with_weights.then(|| rng.he_weights(n * kdim, kdim)),
                    },
                );
                initializers.insert(
                    bname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*n],
                            dtype: DType::F32,
                        },
                        data: with_weights
                            .then(|| (0..*n).map(|_| (rng.normal() * 0.05) as f32).collect()),
                    },
                );
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::Gemm { trans_b: true },
                    inputs: vec![tname.clone(), wname, bname],
                    outputs: vec![out.clone()],
                });
                shape = vec![*n];
                tname = out;
                if *relu {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Relu,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                }
            }
        }
    }
    let out = format!("t{t}");
    nodes.push(Node {
        op: Op::Softmax,
        inputs: vec![tname.clone()],
        outputs: vec![out.clone()],
    });
    Some(Graph {
        name: name.to_string(),
        input_name: "input".into(),
        input: TensorInfo {
            shape: input_shape,
            dtype: DType::F32,
        },
        output_name: out,
        nodes,
        initializers,
    })
}

/// A named tensor with its CHW shape, threaded through [`BranchBuilder`].
#[derive(Clone)]
struct T {
    name: String,
    shape: Vec<usize>,
}

/// Emits branched graphs (residual joins, depthwise convolutions) in
/// the same node/initializer idiom as the linear builder: parameterized
/// layers mint `l{li}_w`/`l{li}_b`, intermediate tensors mint `t{n}`,
/// and every model ends in Softmax.
struct BranchBuilder {
    rng: Rng,
    with_weights: bool,
    nodes: Vec<Node>,
    initializers: HashMap<String, Initializer>,
    t: usize,
    li: usize,
}

impl BranchBuilder {
    fn new(name: &str, with_weights: bool) -> Self {
        BranchBuilder {
            rng: Rng::new(0xC44_2_6A7E ^ name.len() as u64),
            with_weights,
            nodes: Vec::new(),
            initializers: HashMap::new(),
            t: 0,
            li: 0,
        }
    }

    fn fresh(&mut self) -> String {
        let n = format!("t{}", self.t);
        self.t += 1;
        n
    }

    fn weight(&mut self, shape: Vec<usize>, fan_in: usize) -> String {
        let wname = format!("l{}_w", self.li);
        let numel: usize = shape.iter().product();
        let data = if self.with_weights {
            Some(self.rng.he_weights(numel, fan_in))
        } else {
            None
        };
        self.initializers.insert(
            wname.clone(),
            Initializer {
                info: TensorInfo {
                    shape,
                    dtype: DType::F32,
                },
                data,
            },
        );
        wname
    }

    fn bias(&mut self, n: usize) -> String {
        let bname = format!("l{}_b", self.li);
        let data = if self.with_weights {
            Some((0..n).map(|_| (self.rng.normal() * 0.05) as f32).collect())
        } else {
            None
        };
        self.initializers.insert(
            bname.clone(),
            Initializer {
                info: TensorInfo {
                    shape: vec![n],
                    dtype: DType::F32,
                },
                data,
            },
        );
        bname
    }

    fn relu(&mut self, x: &T) -> T {
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::Relu,
            inputs: vec![x.name.clone()],
            outputs: vec![out.clone()],
        });
        T {
            name: out,
            shape: x.shape.clone(),
        }
    }

    /// `groups == cin` (with `cout == cin`) emits a depthwise conv.
    #[allow(clippy::too_many_arguments)]
    fn conv(
        &mut self,
        x: &T,
        cout: usize,
        k: usize,
        s: usize,
        p: usize,
        groups: usize,
        relu: bool,
    ) -> Option<T> {
        let cin = x.shape[0];
        let wname = self.weight(vec![cout, cin / groups, k, k], (cin / groups) * k * k);
        let bname = self.bias(cout);
        self.li += 1;
        let attrs = ConvAttrs {
            kernel: [k, k],
            strides: [s, s],
            pads: [p, p],
            dilations: [1, 1],
            groups,
        };
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::Conv(attrs),
            inputs: vec![x.name.clone(), wname, bname],
            outputs: vec![out.clone()],
        });
        let (oh, ow) = attrs.out_hw(x.shape[1], x.shape[2])?;
        let cur = T {
            name: out,
            shape: vec![cout, oh, ow],
        };
        Some(if relu { self.relu(&cur) } else { cur })
    }

    fn max_pool(&mut self, x: &T, k: usize, s: usize, p: usize) -> Option<T> {
        let attrs = PoolAttrs {
            kernel: [k, k],
            strides: [s, s],
            pads: [p, p],
            dilations: [1, 1],
        };
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::MaxPool(attrs),
            inputs: vec![x.name.clone()],
            outputs: vec![out.clone()],
        });
        let (oh, ow) = attrs.out_hw(x.shape[1], x.shape[2])?;
        Some(T {
            name: out,
            shape: vec![x.shape[0], oh, ow],
        })
    }

    /// Residual join: `a + b`, optionally with a fused trailing Relu.
    /// `a` is the main branch (feed A once fused), `b` the skip path.
    fn add(&mut self, a: &T, b: &T, relu: bool) -> T {
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::Add,
            inputs: vec![a.name.clone(), b.name.clone()],
            outputs: vec![out.clone()],
        });
        let cur = T {
            name: out,
            shape: a.shape.clone(),
        };
        if relu {
            self.relu(&cur)
        } else {
            cur
        }
    }

    fn gap(&mut self, x: &T) -> T {
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::GlobalAveragePool,
            inputs: vec![x.name.clone()],
            outputs: vec![out.clone()],
        });
        T {
            name: out,
            shape: vec![x.shape[0], 1, 1],
        }
    }

    fn fc(&mut self, x: &T, n: usize, relu: bool) -> T {
        let mut cur = x.clone();
        if cur.shape.len() > 1 {
            let out = self.fresh();
            self.nodes.push(Node {
                op: Op::Flatten,
                inputs: vec![cur.name.clone()],
                outputs: vec![out.clone()],
            });
            cur = T {
                name: out,
                shape: vec![cur.shape.iter().product()],
            };
        }
        let kdim = cur.shape[0];
        let wname = self.weight(vec![n, kdim], kdim);
        let bname = self.bias(n);
        self.li += 1;
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::Gemm { trans_b: true },
            inputs: vec![cur.name.clone(), wname, bname],
            outputs: vec![out.clone()],
        });
        let cur = T {
            name: out,
            shape: vec![n],
        };
        if relu {
            self.relu(&cur)
        } else {
            cur
        }
    }

    /// ResNet basic block: 3x3 conv (+relu), 3x3 conv, skip (identity
    /// or 1x1/s projection when the shape changes), Add+relu.
    fn basic_block(&mut self, x: &T, cout: usize, stride: usize) -> Option<T> {
        let c1 = self.conv(x, cout, 3, stride, 1, 1, true)?;
        let c2 = self.conv(&c1, cout, 3, 1, 1, 1, false)?;
        let skip = if stride != 1 || x.shape[0] != cout {
            self.conv(x, cout, 1, stride, 0, 1, false)?
        } else {
            x.clone()
        };
        Some(self.add(&c2, &skip, true))
    }

    fn finish(mut self, name: &str, input_shape: Vec<usize>, last: T) -> Graph {
        let out = self.fresh();
        self.nodes.push(Node {
            op: Op::Softmax,
            inputs: vec![last.name.clone()],
            outputs: vec![out.clone()],
        });
        Graph {
            name: name.to_string(),
            input_name: "input".into(),
            input: TensorInfo {
                shape: input_shape,
                dtype: DType::F32,
            },
            output_name: out,
            nodes: self.nodes,
            initializers: self.initializers,
        }
    }
}

/// ResNet-18 (He et al.): 7x7/2 stem, 3x3/2 max-pool, four stages of
/// two basic blocks (64/128/256/512 channels; stages 2-4 downsample on
/// their first block via a 1x1/2 projection), global average pool and
/// a 1000-way classifier. 11,684,712 parameters (conv/fc + biases,
/// batch-norm folded).
fn build_resnet18(with_weights: bool) -> Option<Graph> {
    let input_shape = vec![3, 224, 224];
    let mut b = BranchBuilder::new("resnet18", with_weights);
    let input = T {
        name: "input".into(),
        shape: input_shape.clone(),
    };
    let mut cur = b.conv(&input, 64, 7, 2, 3, 1, true)?;
    cur = b.max_pool(&cur, 3, 2, 1)?;
    for (cout, stride) in [
        (64, 1),
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
    ] {
        cur = b.basic_block(&cur, cout, stride)?;
    }
    cur = b.gap(&cur);
    cur = b.fc(&cur, 1000, false);
    Some(b.finish("resnet18", input_shape, cur))
}

/// MobileNetV1 (Howard et al.): 3x3/2 stem then thirteen depthwise
/// (3x3, groups == channels) / pointwise (1x1) separable pairs, global
/// average pool, 1000-way classifier. 4,221,032 parameters.
fn build_mobilenetv1(with_weights: bool) -> Option<Graph> {
    let input_shape = vec![3, 224, 224];
    let mut b = BranchBuilder::new("mobilenetv1", with_weights);
    let input = T {
        name: "input".into(),
        shape: input_shape.clone(),
    };
    let mut cur = b.conv(&input, 32, 3, 2, 1, 1, true)?;
    let dw_strides = [1, 2, 1, 2, 1, 2, 1, 1, 1, 1, 1, 2, 1];
    let pw_couts = [64, 128, 128, 256, 256, 512, 512, 512, 512, 512, 512, 1024, 1024];
    for (s, cout) in dw_strides.iter().zip(pw_couts) {
        let ch = cur.shape[0];
        cur = b.conv(&cur, ch, 3, *s, 1, ch, true)?;
        cur = b.conv(&cur, cout, 1, 1, 0, 1, true)?;
    }
    cur = b.gap(&cur);
    cur = b.fc(&cur, 1000, false);
    Some(b.finish("mobilenetv1", input_shape, cur))
}

/// A toy residual+depthwise network sized for exhaustive simulator
/// tests: one basic block plus one separable pair on 8x8 inputs, with
/// channel counts divisible by 4 so tiny (ni, nl) designs admit it.
fn build_tinyres(with_weights: bool) -> Option<Graph> {
    let input_shape = vec![4, 8, 8];
    let mut b = BranchBuilder::new("tinyres", with_weights);
    let input = T {
        name: "input".into(),
        shape: input_shape.clone(),
    };
    let mut cur = b.conv(&input, 8, 3, 1, 1, 1, true)?;
    cur = b.basic_block(&cur, 8, 1)?;
    let ch = cur.shape[0];
    cur = b.conv(&cur, ch, 3, 1, 1, ch, true)?;
    cur = b.conv(&cur, 16, 1, 1, 0, 1, true)?;
    cur = b.gap(&cur);
    cur = b.fc(&cur, 10, false);
    Some(b.finish("tinyres", input_shape, cur))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_models_validate() {
        for name in names() {
            let g = build(name, false).unwrap();
            assert_eq!(g.validate(), Ok(()), "{name}");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("resnet50", false).is_none());
    }

    #[test]
    fn weights_are_deterministic() {
        let a = build("tiny", true).unwrap();
        let b = build("tiny", true).unwrap();
        for (k, init) in &a.initializers {
            assert_eq!(init.data, b.initializers[k].data, "{k}");
        }
    }

    #[test]
    fn param_counts_match_paper() {
        let alex = build("alexnet", false).unwrap();
        assert!((alex.param_count() as f64 / 1e6 - 61.1).abs() < 0.5);
        let vgg = build("vgg16", false).unwrap();
        assert!((vgg.param_count() as f64 / 1e6 - 138.4).abs() < 0.5);
    }

    #[test]
    fn branch_family_param_counts_are_pinned() {
        // conv1 9_472 + stages (147_712 + 524_928 + 2_098_432 +
        // 8_391_168) + fc 513_000.
        let resnet = build("resnet18", false).unwrap();
        assert_eq!(resnet.param_count(), 11_684_712);
        // conv1 896 + depthwise 49_600 + pointwise 3_145_536 +
        // fc 1_025_000.
        let mobile = build("mobilenetv1", false).unwrap();
        assert_eq!(mobile.param_count(), 4_221_032);
    }

    #[test]
    fn branched_models_materialize_deterministic_weights() {
        let a = build("tinyres", true).unwrap();
        let b = build("tinyres", true).unwrap();
        assert!(a.has_weights());
        for (k, init) in &a.initializers {
            assert_eq!(init.data, b.initializers[k].data, "{k}");
            assert_eq!(init.data.as_ref().unwrap().len(), init.info.numel(), "{k}");
        }
    }

    #[test]
    fn mobilenet_depthwise_weights_have_unit_cin() {
        let g = build("mobilenetv1", false).unwrap();
        let dw: Vec<_> = g
            .nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Conv(a) if a.groups > 1 => Some((n, a)),
                _ => None,
            })
            .collect();
        assert_eq!(dw.len(), 13);
        for (n, a) in dw {
            let w = &g.initializers[&n.inputs[1]];
            assert_eq!(w.info.shape[1], 1, "depthwise weight cin/groups");
            assert_eq!(w.info.shape[0], a.groups, "depthwise cout == groups");
        }
    }

    #[test]
    fn with_weights_fills_every_initializer() {
        let g = build("lenet5", true).unwrap();
        assert!(g.has_weights());
        for init in g.initializers.values() {
            assert_eq!(init.data.as_ref().unwrap().len(), init.info.numel());
        }
    }
}
