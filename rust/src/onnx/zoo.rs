//! Model zoo: programmatic builders for the topologies the paper
//! evaluates (AlexNet, VGG-16) plus LeNet-5 and a tiny test CNN.
//!
//! These mirror `python/compile/model.py` layer-for-layer; the pytest /
//! cargo integration tests cross-check both sides against the ONNX-subset
//! JSON emitted by `make artifacts`.

use std::collections::HashMap;

use crate::ir::{ConvAttrs, DType, Graph, Initializer, Node, Op, PoolAttrs, TensorInfo};
use crate::util::rng::Rng;

/// Internal layer description used by the builders.
enum L {
    Conv {
        cout: usize,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
    },
    Pool {
        k: usize,
        s: usize,
    },
    Fc {
        n: usize,
        relu: bool,
    },
}

fn conv(cout: usize, k: usize, s: usize, p: usize) -> L {
    L::Conv {
        cout,
        k,
        s,
        p,
        relu: true,
    }
}

fn pool(k: usize, s: usize) -> L {
    L::Pool { k, s }
}

fn fc(n: usize) -> L {
    L::Fc { n, relu: true }
}

fn fc_last(n: usize) -> L {
    L::Fc { n, relu: false }
}

fn spec(name: &str) -> Option<(Vec<usize>, Vec<L>)> {
    let layers = match name {
        "tiny" => (
            vec![1, 8, 8],
            vec![conv(4, 3, 1, 1), pool(2, 2), fc_last(10)],
        ),
        "lenet5" => (
            vec![1, 28, 28],
            vec![
                conv(6, 5, 1, 2),
                pool(2, 2),
                conv(16, 5, 1, 0),
                pool(2, 2),
                fc(120),
                fc(84),
                fc_last(10),
            ],
        ),
        "alexnet" => (
            vec![3, 224, 224],
            vec![
                conv(64, 11, 4, 2),
                pool(3, 2),
                conv(192, 5, 1, 2),
                pool(3, 2),
                conv(384, 3, 1, 1),
                conv(256, 3, 1, 1),
                conv(256, 3, 1, 1),
                pool(3, 2),
                fc(4096),
                fc(4096),
                fc_last(1000),
            ],
        ),
        "vgg16" => {
            let mut ls = Vec::new();
            for (reps, cout) in [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)] {
                for _ in 0..reps {
                    ls.push(conv(cout, 3, 1, 1));
                }
                ls.push(pool(2, 2));
            }
            ls.push(fc(4096));
            ls.push(fc(4096));
            ls.push(fc_last(1000));
            (vec![3, 224, 224], ls)
        }
        _ => return None,
    };
    Some(layers)
}

/// Names available in the zoo.
pub fn names() -> &'static [&'static str] {
    &["tiny", "lenet5", "alexnet", "vgg16"]
}

/// Build a zoo model. `with_weights` materializes He-initialized
/// synthetic parameters (deterministic seed per model); without it the
/// initializers carry shape/dtype only (ONNX external-data style).
pub fn build(name: &str, with_weights: bool) -> Option<Graph> {
    let (input_shape, layers) = spec(name)?;
    let mut rng = Rng::new(0xC44_2_6A7E ^ name.len() as u64);
    let mut nodes = Vec::new();
    let mut initializers = HashMap::new();
    let mut tname = "input".to_string();
    let mut t = 0usize;
    let mut shape = input_shape.clone();
    let fresh = |t: &mut usize| {
        let n = format!("t{t}");
        *t += 1;
        n
    };
    for (li, layer) in layers.iter().enumerate() {
        match layer {
            L::Conv { cout, k, s, p, relu } => {
                let cin = shape[0];
                let (wname, bname) = (format!("l{li}_w"), format!("l{li}_b"));
                let wlen = cout * cin * k * k;
                initializers.insert(
                    wname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*cout, cin, *k, *k],
                            dtype: DType::F32,
                        },
                        data: with_weights.then(|| rng.he_weights(wlen, cin * k * k)),
                    },
                );
                initializers.insert(
                    bname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*cout],
                            dtype: DType::F32,
                        },
                        data: with_weights
                            .then(|| (0..*cout).map(|_| (rng.normal() * 0.05) as f32).collect()),
                    },
                );
                let attrs = ConvAttrs {
                    kernel: [*k, *k],
                    strides: [*s, *s],
                    pads: [*p, *p],
                    dilations: [1, 1],
                };
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::Conv(attrs),
                    inputs: vec![tname.clone(), wname, bname],
                    outputs: vec![out.clone()],
                });
                let (oh, ow) = attrs.out_hw(shape[1], shape[2])?;
                shape = vec![*cout, oh, ow];
                tname = out;
                if *relu {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Relu,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                }
            }
            L::Pool { k, s } => {
                let attrs = PoolAttrs {
                    kernel: [*k, *k],
                    strides: [*s, *s],
                    pads: [0, 0],
                };
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::MaxPool(attrs),
                    inputs: vec![tname.clone()],
                    outputs: vec![out.clone()],
                });
                let (oh, ow) = attrs.out_hw(shape[1], shape[2])?;
                shape = vec![shape[0], oh, ow];
                tname = out;
            }
            L::Fc { n, relu } => {
                if shape.len() > 1 {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Flatten,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                    shape = vec![shape.iter().product()];
                }
                let kdim = shape[0];
                let (wname, bname) = (format!("l{li}_w"), format!("l{li}_b"));
                initializers.insert(
                    wname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*n, kdim],
                            dtype: DType::F32,
                        },
                        data: with_weights.then(|| rng.he_weights(n * kdim, kdim)),
                    },
                );
                initializers.insert(
                    bname.clone(),
                    Initializer {
                        info: TensorInfo {
                            shape: vec![*n],
                            dtype: DType::F32,
                        },
                        data: with_weights
                            .then(|| (0..*n).map(|_| (rng.normal() * 0.05) as f32).collect()),
                    },
                );
                let out = fresh(&mut t);
                nodes.push(Node {
                    op: Op::Gemm { trans_b: true },
                    inputs: vec![tname.clone(), wname, bname],
                    outputs: vec![out.clone()],
                });
                shape = vec![*n];
                tname = out;
                if *relu {
                    let out = fresh(&mut t);
                    nodes.push(Node {
                        op: Op::Relu,
                        inputs: vec![tname.clone()],
                        outputs: vec![out.clone()],
                    });
                    tname = out;
                }
            }
        }
    }
    let out = format!("t{t}");
    nodes.push(Node {
        op: Op::Softmax,
        inputs: vec![tname.clone()],
        outputs: vec![out.clone()],
    });
    Some(Graph {
        name: name.to_string(),
        input_name: "input".into(),
        input: TensorInfo {
            shape: input_shape,
            dtype: DType::F32,
        },
        output_name: out,
        nodes,
        initializers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_models_validate() {
        for name in names() {
            let g = build(name, false).unwrap();
            assert_eq!(g.validate(), Ok(()), "{name}");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("resnet50", false).is_none());
    }

    #[test]
    fn weights_are_deterministic() {
        let a = build("tiny", true).unwrap();
        let b = build("tiny", true).unwrap();
        for (k, init) in &a.initializers {
            assert_eq!(init.data, b.initializers[k].data, "{k}");
        }
    }

    #[test]
    fn param_counts_match_paper() {
        let alex = build("alexnet", false).unwrap();
        assert!((alex.param_count() as f64 / 1e6 - 61.1).abs() < 0.5);
        let vgg = build("vgg16", false).unwrap();
        assert!((vgg.param_count() as f64 / 1e6 - 138.4).abs() < 0.5);
    }

    #[test]
    fn with_weights_fills_every_initializer() {
        let g = build("lenet5", true).unwrap();
        assert!(g.has_weights());
        for init in g.initializers.values() {
            assert_eq!(init.data.as_ref().unwrap().len(), init.info.numel());
        }
    }
}
