//! ONNX front-end: the generalized model-analysis layer of paper §4.1.
//!
//! `parser` reads the ONNX-subset exchange files — the operator set
//! covers {Conv (grouped/dilated included), MaxPool, Relu, Flatten,
//! Gemm, Softmax, Add, GlobalAveragePool}, so residual and
//! depthwise/separable graphs parse alongside the linear chains;
//! `zoo` builds the evaluation topologies programmatically (AlexNet,
//! VGG-16, LeNet-5, tiny, plus the branched families: resnet18,
//! mobilenetv1, tinyres). Both produce the same [`crate::ir::Graph`]
//! IR.

pub mod parser;
pub mod zoo;

pub use parser::{parse_doc, parse_file};
