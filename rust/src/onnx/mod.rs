//! ONNX front-end: the generalized model-analysis layer of paper §4.1.
//!
//! `parser` reads the ONNX-subset exchange files; `zoo` builds the
//! evaluation topologies programmatically (AlexNet, VGG-16, LeNet-5,
//! tiny). Both produce the same [`crate::ir::Graph`] IR.

pub mod parser;
pub mod zoo;

pub use parser::{parse_doc, parse_file};
