//! ONNX-subset front-end parser (paper §4.1).
//!
//! Reads the `cnn2gate-onnx-subset-v1` JSON files written by
//! `python/compile/aot.py` (and by hand, if a user authors one): an
//! acyclic node list over the operator set {Conv (grouped/dilated
//! included), MaxPool, Relu, Flatten, Gemm, Softmax, Add,
//! GlobalAveragePool}, with initializer tensors stored in an external
//! raw little-endian sidecar, exactly like ONNX's external-data
//! convention. Add takes two activation inputs (the residual join);
//! everything the DAG flow extractor needs rides the node list as-is.
//!
//! The parser extracts the computation data-flow *plus weights and
//! biases* (paper: "parses the computation dataflow — or the arrangement
//! of layers — besides weights and biases for each layer") into the
//! [`Graph`] IR, then shape inference and flow extraction run on top.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::{Attrs, ConvAttrs, DType, Graph, Initializer, Node, Op, PoolAttrs, TensorInfo};
use crate::util::json::Json;

pub const FORMAT: &str = "cnn2gate-onnx-subset-v1";

/// Parse a model file; if it names external data, the sidecar is read
/// from the same directory.
pub fn parse_file(path: &Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading model file {}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let external = doc.get("external_data").as_str().map(|f| {
        path.parent()
            .unwrap_or_else(|| Path::new("."))
            .join(f)
    });
    let raw = match &external {
        Some(p) => Some(
            std::fs::read(p).with_context(|| format!("reading external data {}", p.display()))?,
        ),
        None => None,
    };
    parse_doc(&doc, raw.as_deref())
}

/// Parse from an already-loaded JSON document (+ optional raw data blob).
pub fn parse_doc(doc: &Json, raw: Option<&[u8]>) -> Result<Graph> {
    if doc.get("format").as_str() != Some(FORMAT) {
        bail!(
            "unsupported model format {:?} (want {FORMAT})",
            doc.get("format").as_str()
        );
    }
    let name = doc
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("model missing 'name'"))?
        .to_string();

    let input = doc.get("input");
    let input_name = input
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("input missing 'name'"))?
        .to_string();
    let input_shape = input
        .get("shape")
        .as_usize_vec()
        .ok_or_else(|| anyhow!("input missing 'shape'"))?;
    let input_dtype = DType::parse(input.get("dtype").as_str().unwrap_or("float32"))
        .ok_or_else(|| anyhow!("bad input dtype"))?;

    let output_name = doc
        .get("output")
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("output missing 'name'"))?
        .to_string();

    // -- initializers -------------------------------------------------------
    let mut initializers = HashMap::new();
    for (i, init) in doc
        .get("initializers")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .enumerate()
    {
        let iname = init
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("initializer {i} missing name"))?
            .to_string();
        let shape = init
            .get("shape")
            .as_usize_vec()
            .ok_or_else(|| anyhow!("initializer '{iname}' missing shape"))?;
        let dtype = DType::parse(init.get("dtype").as_str().unwrap_or("float32"))
            .ok_or_else(|| anyhow!("initializer '{iname}' bad dtype"))?;
        let info = TensorInfo {
            shape,
            dtype,
        };
        let data = match raw {
            Some(bytes) => {
                let offset = init
                    .get("offset")
                    .as_usize()
                    .ok_or_else(|| anyhow!("initializer '{iname}' missing offset"))?;
                let nbytes = init
                    .get("nbytes")
                    .as_usize()
                    .ok_or_else(|| anyhow!("initializer '{iname}' missing nbytes"))?;
                if nbytes != info.nbytes() {
                    bail!(
                        "initializer '{iname}': declared {nbytes} bytes but shape implies {}",
                        info.nbytes()
                    );
                }
                let end = offset
                    .checked_add(nbytes)
                    .filter(|&e| e <= bytes.len())
                    .ok_or_else(|| anyhow!("initializer '{iname}' range out of bounds"))?;
                if dtype != DType::F32 {
                    bail!("external data only supports float32 initializers");
                }
                let floats: Vec<f32> = bytes[offset..end]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Some(floats)
            }
            None => None,
        };
        initializers.insert(iname, Initializer { info, data });
    }

    // -- nodes ---------------------------------------------------------------
    let mut nodes = Vec::new();
    for (i, n) in doc.get("nodes").as_arr().unwrap_or(&[]).iter().enumerate() {
        let op_type = n
            .get("op_type")
            .as_str()
            .ok_or_else(|| anyhow!("node {i} missing op_type"))?;
        // a non-string entry is a malformed model, not an edge to drop
        // silently — report it instead of failing later with a puzzling
        // arity or undefined-tensor error
        let inputs = string_list(n.get("inputs"), &format!("node {i} ({op_type}) inputs"))?;
        let outputs = string_list(n.get("outputs"), &format!("node {i} ({op_type}) outputs"))?;
        if outputs.is_empty() {
            bail!("node {i} ({op_type}) has no outputs");
        }
        let attrs = parse_attrs(n.get("attrs"));
        let op = build_op(op_type, &attrs)
            .with_context(|| format!("node {i} ({op_type})"))?;
        let arity_ok = match &op {
            Op::Conv(_) => inputs.len() == 2 || inputs.len() == 3,
            Op::Gemm { .. } => inputs.len() == 2 || inputs.len() == 3,
            Op::Add => inputs.len() == 2,
            _ => inputs.len() == 1,
        };
        if !arity_ok {
            bail!("node {i} ({op_type}) has wrong arity {}", inputs.len());
        }
        nodes.push(Node {
            op,
            inputs,
            outputs,
        });
    }

    let graph = Graph {
        name,
        input_name,
        input: TensorInfo {
            shape: input_shape,
            dtype: input_dtype,
        },
        output_name,
        nodes,
        initializers,
    };
    graph.validate().map_err(|e| anyhow!("invalid graph: {e}"))?;
    Ok(graph)
}

fn string_list(v: &Json, what: &str) -> Result<Vec<String>> {
    let arr = v.as_arr().unwrap_or(&[]);
    let mut out = Vec::with_capacity(arr.len());
    for (j, item) in arr.iter().enumerate() {
        out.push(
            item.as_str()
                .map(String::from)
                .ok_or_else(|| anyhow!("{what}[{j}] must be a string"))?,
        );
    }
    Ok(out)
}

fn parse_attrs(a: &Json) -> Attrs {
    Attrs {
        kernel_shape: a.get("kernel_shape").as_usize_vec(),
        strides: a.get("strides").as_usize_vec(),
        pads: a.get("pads").as_usize_vec(),
        dilations: a.get("dilations").as_usize_vec(),
        group: a.get("group").as_usize(),
        trans_b: a.get("transB").as_i64().map(|v| v != 0),
    }
}

fn pair(v: &Option<Vec<usize>>, default: [usize; 2], what: &str) -> Result<[usize; 2]> {
    match v {
        None => Ok(default),
        Some(xs) if xs.len() == 2 => Ok([xs[0], xs[1]]),
        Some(xs) => bail!("{what} must have 2 entries, got {}", xs.len()),
    }
}

/// ONNX 4-element pads [top, left, bottom, right] must be symmetric for
/// the pipelined architecture; fold them to [h, w].
fn fold_pads(v: &Option<Vec<usize>>) -> Result<[usize; 2]> {
    match v {
        None => Ok([0, 0]),
        Some(xs) if xs.len() == 2 => Ok([xs[0], xs[1]]),
        Some(xs) if xs.len() == 4 => {
            if xs[0] != xs[2] || xs[1] != xs[3] {
                bail!("asymmetric pads {xs:?} unsupported by the pipeline");
            }
            Ok([xs[0], xs[1]])
        }
        Some(xs) => bail!("pads must have 2 or 4 entries, got {}", xs.len()),
    }
}

fn build_op(op_type: &str, attrs: &Attrs) -> Result<Op> {
    Ok(match op_type {
        "Conv" => {
            let kernel = attrs
                .kernel_shape
                .as_ref()
                .ok_or_else(|| anyhow!("Conv missing kernel_shape"))?;
            let kernel = pair(&Some(kernel.clone()), [1, 1], "kernel_shape")?;
            let groups = attrs.group.unwrap_or(1);
            if groups == 0 {
                bail!("Conv group must be >= 1");
            }
            Op::Conv(ConvAttrs {
                kernel,
                strides: pair(&attrs.strides, [1, 1], "strides")?,
                pads: fold_pads(&attrs.pads)?,
                dilations: pair(&attrs.dilations, [1, 1], "dilations")?,
                groups,
            })
        }
        "MaxPool" => {
            let kernel = attrs
                .kernel_shape
                .as_ref()
                .ok_or_else(|| anyhow!("MaxPool missing kernel_shape"))?;
            let kernel = pair(&Some(kernel.clone()), [1, 1], "kernel_shape")?;
            Op::MaxPool(PoolAttrs {
                kernel,
                strides: pair(&attrs.strides, kernel, "strides")?,
                pads: fold_pads(&attrs.pads)?,
                dilations: pair(&attrs.dilations, [1, 1], "dilations")?,
            })
        }
        "Relu" => Op::Relu,
        "Flatten" => Op::Flatten,
        "Gemm" => Op::Gemm {
            trans_b: attrs.trans_b.unwrap_or(false),
        },
        "Softmax" => Op::Softmax,
        "Add" => Op::Add,
        "GlobalAveragePool" => Op::GlobalAveragePool,
        other => bail!("unsupported operator '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_doc(extra_node: &str) -> String {
        format!(
            r#"{{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "m",
  "input": {{"name": "input", "shape": [1, 4, 4], "dtype": "float32"}},
  "output": {{"name": "y"}},
  "nodes": [{extra_node}],
  "initializers": [
    {{"name": "w", "shape": [2, 1, 3, 3], "dtype": "float32", "offset": 0, "nbytes": 72}},
    {{"name": "b", "shape": [2], "dtype": "float32", "offset": 72, "nbytes": 8}}
  ],
  "external_data": null
}}"#
        )
    }

    const CONV: &str = r#"{"op_type": "Conv", "inputs": ["input", "w", "b"], "outputs": ["y"],
        "attrs": {"kernel_shape": [3, 3], "strides": [1, 1], "pads": [1, 1, 1, 1], "dilations": [1, 1]}}"#;

    #[test]
    fn parses_minimal_conv_model() {
        let doc = Json::parse(&minimal_doc(CONV)).unwrap();
        let g = parse_doc(&doc, None).unwrap();
        assert_eq!(g.nodes.len(), 1);
        match &g.nodes[0].op {
            Op::Conv(a) => assert_eq!(a.pads, [1, 1]),
            _ => panic!(),
        }
        assert!(!g.has_weights()); // no raw blob supplied
    }

    #[test]
    fn reads_external_data() {
        let doc = Json::parse(&minimal_doc(CONV)).unwrap();
        let mut blob = Vec::new();
        for i in 0..20 {
            blob.extend_from_slice(&(i as f32).to_le_bytes());
        }
        let g = parse_doc(&doc, Some(&blob)).unwrap();
        assert!(g.has_weights());
        assert_eq!(g.initializers["w"].data.as_ref().unwrap()[3], 3.0);
        assert_eq!(g.initializers["b"].data.as_ref().unwrap()[0], 18.0);
    }

    #[test]
    fn rejects_asymmetric_pads() {
        let node = CONV.replace("[1, 1, 1, 1]", "[1, 0, 2, 1]");
        let doc = Json::parse(&minimal_doc(&node)).unwrap();
        let err = format!("{:#}", parse_doc(&doc, None).unwrap_err());
        assert!(err.contains("asymmetric"), "{err}");
    }

    #[test]
    fn rejects_non_string_node_edges() {
        let node = CONV.replace(r#"["input", "w", "b"]"#, r#"["input", 7, "b"]"#);
        let doc = Json::parse(&minimal_doc(&node)).unwrap();
        let err = format!("{:#}", parse_doc(&doc, None).unwrap_err());
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn rejects_unknown_operator() {
        let node = CONV.replace("\"Conv\"", "\"BatchNorm\"");
        let doc = Json::parse(&minimal_doc(&node)).unwrap();
        let err = format!("{:#}", parse_doc(&doc, None).unwrap_err());
        assert!(err.contains("unsupported operator"), "{err}");
    }

    #[test]
    fn rejects_wrong_format() {
        let doc = Json::parse(&minimal_doc(CONV).replace("subset-v1", "subset-v9")).unwrap();
        assert!(parse_doc(&doc, None).is_err());
    }

    #[test]
    fn rejects_out_of_bounds_external_range() {
        let doc = Json::parse(&minimal_doc(CONV)).unwrap();
        let blob = vec![0u8; 16]; // far too small
        assert!(parse_doc(&doc, Some(&blob)).is_err());
    }

    #[test]
    fn rejects_nbytes_shape_mismatch() {
        let text = minimal_doc(CONV).replace("\"nbytes\": 72", "\"nbytes\": 80");
        let doc = Json::parse(&text).unwrap();
        let blob = vec![0u8; 128];
        assert!(parse_doc(&doc, Some(&blob))
            .unwrap_err()
            .to_string()
            .contains("shape implies"));
    }

    #[test]
    fn parses_grouped_and_dilated_conv() {
        let node = CONV.replace(
            r#""dilations": [1, 1]"#,
            r#""dilations": [2, 2], "group": 1"#,
        );
        let doc = Json::parse(&minimal_doc(&node)).unwrap();
        let g = parse_doc(&doc, None).unwrap();
        match &g.nodes[0].op {
            Op::Conv(a) => {
                assert_eq!(a.dilations, [2, 2]);
                assert_eq!(a.groups, 1);
            }
            _ => panic!(),
        }
        // absent group defaults to 1 (dense)
        let doc = Json::parse(&minimal_doc(CONV)).unwrap();
        match &parse_doc(&doc, None).unwrap().nodes[0].op {
            Op::Conv(a) => assert_eq!(a.groups, 1),
            _ => panic!(),
        }
        // group 0 is rejected at parse time, before shape inference
        let node = CONV.replace(r#""dilations": [1, 1]"#, r#""dilations": [1, 1], "group": 0"#);
        let doc = Json::parse(&minimal_doc(&node)).unwrap();
        let err = format!("{:#}", parse_doc(&doc, None).unwrap_err());
        assert!(err.contains("group must be >= 1"), "{err}");
    }

    #[test]
    fn parses_residual_add_and_gap() {
        // a residual bypass: conv -> add(input, conv) -> gap, the exact
        // structure a ResNet block tail lowers to
        let text = r#"{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "res",
  "input": {"name": "input", "shape": [2, 4, 4], "dtype": "float32"},
  "output": {"name": "out"},
  "nodes": [
    {"op_type": "Conv", "inputs": ["input", "w"], "outputs": ["c"],
     "attrs": {"kernel_shape": [3, 3], "strides": [1, 1], "pads": [1, 1, 1, 1]}},
    {"op_type": "Add", "inputs": ["input", "c"], "outputs": ["s"], "attrs": {}},
    {"op_type": "GlobalAveragePool", "inputs": ["s"], "outputs": ["out"], "attrs": {}}
  ],
  "initializers": [
    {"name": "w", "shape": [2, 2, 3, 3], "dtype": "float32", "offset": 0, "nbytes": 144}
  ],
  "external_data": null
}"#;
        let doc = Json::parse(text).unwrap();
        let g = parse_doc(&doc, None).unwrap();
        assert_eq!(g.op_names(), vec!["Conv", "Add", "GlobalAveragePool"]);
        let flow = crate::ir::ComputationFlow::extract(&g).unwrap();
        // conv round + Add merge + GAP pass-through round
        assert_eq!(flow.layers.len(), 3);
        assert_eq!(flow.layers[1].producers, vec![0], "input branch is a graph feed");
        assert!(!flow.layers[1].has_weights());
        // a one-input Add is an arity error, not a later shape panic
        let bad_text = text.replace(r#"["input", "c"]"#, r#"["c"]"#);
        let bad = Json::parse(&bad_text).unwrap();
        let err = format!("{:#}", parse_doc(&bad, None).unwrap_err());
        assert!(err.contains("wrong arity"), "{err}");
    }

    #[test]
    fn roundtrips_zoo_models_via_validate() {
        // zoo -> (conceptual) JSON happens in python; here ensure parser
        // accepts the exact structure aot.py writes for a pool+gemm chain.
        let doc = Json::parse(
            r#"{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "m2",
  "input": {"name": "input", "shape": [2, 4, 4], "dtype": "float32"},
  "output": {"name": "out"},
  "nodes": [
    {"op_type": "MaxPool", "inputs": ["input"], "outputs": ["p"],
     "attrs": {"kernel_shape": [2, 2], "strides": [2, 2], "pads": [0, 0, 0, 0]}},
    {"op_type": "Flatten", "inputs": ["p"], "outputs": ["f"], "attrs": {}},
    {"op_type": "Gemm", "inputs": ["f", "w", "b"], "outputs": ["g"], "attrs": {"transB": 1}},
    {"op_type": "Softmax", "inputs": ["g"], "outputs": ["out"], "attrs": {}}
  ],
  "initializers": [
    {"name": "w", "shape": [3, 8], "dtype": "float32", "offset": 0, "nbytes": 96},
    {"name": "b", "shape": [3], "dtype": "float32", "offset": 96, "nbytes": 12}
  ],
  "external_data": null
}"#,
        )
        .unwrap();
        let g = parse_doc(&doc, None).unwrap();
        assert_eq!(g.op_names(), vec!["MaxPool", "Flatten", "Gemm", "Softmax"]);
        let flow = crate::ir::ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.layers.len(), 2); // pass-through pool round + fc
    }
}
