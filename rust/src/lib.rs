//! # CNN2Gate (reproduction)
//!
//! A general framework for implementing convolutional neural networks on
//! FPGA — Ghaffari & Savaria, 2020 — rebuilt as a three-layer Rust + JAX
//! + Pallas stack with simulated hardware substrates (see DESIGN.md).
//!
//! ## The front door: [`session`]
//!
//! The whole parse → quantize → DSE → synth flow sits behind one typed
//! entry point. A [`session::Session`] (built via
//! [`session::SessionBuilder`]) owns the run-scoped machinery — the
//! [`dse::Evaluator`] worker pool + estimator memo, the
//! [`session::CachePolicy`] disk lifecycle, the [`dse::Fidelity`] and
//! [`estimator::Thresholds`] — and a [`session::CompileJob`] names the
//! work: models × devices × [`synth::Explorer`] × optional
//! [`quant::QuantSpec`]. [`session::Session::run`] executes the job on
//! a two-phase work-stealing engine ([`coordinator::scheduler`]) and
//! returns a [`session::Outcome`] whose 1×1, 1×N and M×N shapes are the
//! classic synth report, fleet fit and model×device sweep — plus a
//! stable machine-readable [`session::Outcome::to_json`] document
//! (`--json` on the CLI, pinned byte-for-byte by process-level golden
//! tests). The PR-4 deprecated free-function shims are gone; the
//! session is the only entry point, and `rust/tests/session.rs` pins
//! its determinism run-vs-run, cold and cache-warm.
//!
//! ## The layers underneath
//!
//! Pipeline: [`onnx`] parses a model into the [`ir`] graph; [`quant`]
//! applies the user-given fixed-point formats; [`dse`] explores the
//! `(N_i, N_l)` parallelism options against the [`estimator`]'s resource
//! model; [`synth`] defines the per-target synthesis report; [`sim`]
//! executes the deeply pipelined kernel architecture cycle-by-cycle for
//! latency; [`runtime`] runs the AOT-compiled JAX/Pallas emulation path
//! on the PJRT CPU client (behind the `pjrt` feature; the default build
//! substitutes an API-identical stub); [`coordinator`] wires model
//! loading and the legacy report views into the end-to-end flow the CLI
//! and examples drive, and hosts [`coordinator::service`] — the
//! long-lived compile-service daemon that multiplexes concurrent
//! [`coordinator::service::JobSpec`] submissions and batched inference
//! requests onto one shared evaluator, with admission control,
//! per-tenant fairness, streamed [`coordinator::service::Event`]s and a
//! replayable reducer log (`serve` on the CLI).
//!
//! Exploration scales through [`dse::eval`], the shared evaluation
//! core: a `std::thread` + channel worker pool fans candidate scoring
//! out across cores (bit-identical results to the sequential path) and
//! a memo cache keyed on `(model fingerprint, device fingerprint, N_i,
//! N_l, fidelity, census γ, tenant)` — scoring knobs travel as one
//! [`dse::EvalRequest`], and the [`dse::TenantId`] namespace keeps
//! multi-tenant service traffic from cross-contaminating memo entries —
//! deduplicates the estimator + simulator queries that the RL/joint
//! agents revisit constantly. The memo
//! persists: the FNV fingerprints are process-stable, so
//! [`dse::CacheStore`] keeps a sharded, append-only store on disk
//! (`--cache-dir` on the CLI) — one line-delimited shard per
//! (tenant, model) with a differential delta log, compaction and an
//! advisory file lock for concurrent sessions — and repeat
//! explorations across processes start warm. The legacy
//! single-file [`dse::EvalCache`] format (`--cache-file`,
//! LRU-bounded by `--cache-max-entries`) still loads and migrates
//! into the store one-shot. Ground truth is affordable: the cycle-stepped
//! simulator's **epoch skip-ahead engine** ([`sim::step_round`], exact
//! u128 fixed-point fractional DDR credit via [`sim::ddr_credit_rate`])
//! fast-forwards steady-state stretches in closed form — bit-identical
//! to the naive stepper, orders of magnitude faster — which makes
//! [`dse::Fidelity::SteppedFullNetwork`] (every round stepped,
//! per-layer stall census) usable inside DSE loops. The census is an
//! *input* now, not just a report: `--census-gamma` shapes every
//! explorer's Algorithm-1 reward with the bottleneck round's stall
//! fraction ([`dse::RewardShaper`]), and [`mod@dse::specialize`] re-folds
//! the uniform winner to per-layer `(N_i, N_l)` options and weight
//! schedules (`synth --specialize`,
//! [`report::tables::specialization_table`]). Every session run —
//! fleet fits and the RL agents' episode batches included — rides
//! [`coordinator::scheduler`]'s work-stealing deques, rendered via
//! [`report::tables::sweep_table`] with best-device-per-model /
//! best-model-per-device rankings and the latency/resource Pareto
//! frontier.

pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod estimator;
pub mod ir;
pub mod metrics;
pub mod onnx;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod synth;
pub mod testkit;
pub mod util;
