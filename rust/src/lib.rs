//! # CNN2Gate (reproduction)
//!
//! A general framework for implementing convolutional neural networks on
//! FPGA — Ghaffari & Savaria, 2020 — rebuilt as a three-layer Rust + JAX
//! + Pallas stack with simulated hardware substrates (see DESIGN.md).
//!
//! Pipeline: [`onnx`] parses a model into the [`ir`] graph; [`quant`]
//! applies the user-given fixed-point formats; [`dse`] explores the
//! `(N_i, N_l)` parallelism options against the [`estimator`]'s resource
//! model; [`synth`] orchestrates the (simulated) synthesis flow; [`sim`]
//! executes the deeply pipelined kernel architecture cycle-by-cycle for
//! latency; [`runtime`] runs the AOT-compiled JAX/Pallas emulation path
//! on the PJRT CPU client; [`coordinator`] wires it all into the
//! end-to-end flow the CLI and examples drive.

pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod estimator;
pub mod ir;
pub mod metrics;
pub mod onnx;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod testkit;
pub mod util;
