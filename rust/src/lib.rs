//! # CNN2Gate (reproduction)
//!
//! A general framework for implementing convolutional neural networks on
//! FPGA — Ghaffari & Savaria, 2020 — rebuilt as a three-layer Rust + JAX
//! + Pallas stack with simulated hardware substrates (see DESIGN.md).
//!
//! Pipeline: [`onnx`] parses a model into the [`ir`] graph; [`quant`]
//! applies the user-given fixed-point formats; [`dse`] explores the
//! `(N_i, N_l)` parallelism options against the [`estimator`]'s resource
//! model; [`synth`] orchestrates the (simulated) synthesis flow; [`sim`]
//! executes the deeply pipelined kernel architecture cycle-by-cycle for
//! latency; [`runtime`] runs the AOT-compiled JAX/Pallas emulation path
//! on the PJRT CPU client (behind the `pjrt` feature; the default build
//! substitutes an API-identical stub); [`coordinator`] wires it all into
//! the end-to-end flow the CLI and examples drive.
//!
//! Exploration scales through [`dse::eval`], the shared evaluation
//! core: a `std::thread` + channel worker pool fans candidate scoring
//! out across cores (bit-identical results to the sequential path) and
//! a memo cache keyed on `(model fingerprint, device fingerprint, N_i,
//! N_l, fidelity)` deduplicates the estimator + simulator queries that
//! the RL/joint agents revisit constantly. The memo persists: the FNV
//! fingerprints are process-stable, so [`dse::EvalCache`] serializes to
//! a versioned, corruption-tolerant JSON file (`--cache-file` on the
//! CLI, LRU-bounded by `--cache-max-entries`) and repeat explorations
//! across processes start warm. Ground truth is affordable: the
//! cycle-stepped simulator's **epoch skip-ahead engine**
//! ([`sim::step_round`]) fast-forwards steady-state stretches in closed
//! form — bit-identical to the naive stepper, orders of magnitude
//! faster — which makes [`dse::Fidelity::SteppedFullNetwork`] (every
//! round stepped, per-layer stall census) usable inside DSE loops. On
//! top of it, [`coordinator::pipeline::fit_fleet`] (CLI: `fit-fleet`)
//! fits one model against every device in [`estimator::device`]
//! concurrently, and [`coordinator::pipeline::sweep_matrix`] (CLI:
//! `sweep`) explores the full model×device matrix on a work-stealing
//! scheduler ([`coordinator::scheduler`]), rendered via
//! [`report::tables::sweep_table`] with best-device-per-model /
//! best-model-per-device rankings and the latency/resource Pareto
//! frontier.

pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod estimator;
pub mod ir;
pub mod metrics;
pub mod onnx;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod testkit;
pub mod util;
