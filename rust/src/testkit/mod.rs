//! Property-testing kit (the offline image has no `proptest`).
//!
//! A deliberately small shrinking-free QuickCheck: seeded generators over
//! the repo's PRNG + a case runner that reports the failing seed so any
//! counterexample is reproducible with `PROP_SEED=<n> cargo test`.
//!
//! Used by the DSE, simulator and quantizer invariants (DESIGN.md §5.14).

use crate::util::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5eed_cafe)
}

/// Run `prop` for `cases()` seeds; panic with the failing seed on error.
///
/// ```no_run
/// # // no_run: doctest binaries lack the xla rpath in this image
/// use cnn2gate::testkit::{for_all, Gen};
/// for_all("addition commutes", |g| {
///     let (a, b) = (g.int(-1000, 1000), g.int(-1000, 1000));
///     assert_eq!(a + b, b + a);
/// });
/// ```
pub fn for_all(name: &str, prop: impl Fn(&mut Gen)) {
    let n = cases();
    let base = base_seed();
    for case in 0..n {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (PROP_SEED={seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choose(items)
    }

    /// Vector of `len` draws from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// A power of two in `[2^lo_exp, 2^hi_exp]`.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        1usize << self.int(lo_exp as i64, hi_exp as i64)
    }

    /// f32 tensor with normal(0, scale) entries.
    pub fn tensor(&mut self, len: usize, scale: f64) -> Vec<f32> {
        (0..len).map(|_| (self.rng.normal() * scale) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        for_all("counter", |_| {
            COUNT.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(COUNT.load(Ordering::Relaxed), cases());
    }

    #[test]
    fn generators_respect_bounds() {
        for_all("bounds", |g| {
            let v = g.int(-5, 9);
            assert!((-5..=9).contains(&v));
            let p = g.pow2(2, 6);
            assert!(p.is_power_of_two() && (4..=64).contains(&p));
            let x = g.f64(1.5, 2.5);
            assert!((1.5..2.5).contains(&x) || x == 2.5);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_propagates() {
        for_all("always fails", |g| {
            assert!(g.int(0, 10) > 100);
        });
    }
}
