//! `cnn2gate` — leader entrypoint + CLI.
//!
//! Subcommands mirror the paper's workflow (Fig. 4a):
//!   info      parse a model, print the extracted computation flow
//!   dse       design-space exploration on a device (RL or brute force)
//!   fit-fleet fit one model on every device in the database, in parallel
//!   sweep     explore every (model, device) pair: rankings + Pareto frontier
//!   synth     full (simulated) synthesis flow: DSE + fit + latency
//!   emulate   emulation mode: run the AOT artifacts through PJRT
//!   serve     batched emulation-inference server demo
//!   tables    regenerate the paper's Tables 1-4 + Fig. 6
//!   devices   list the FPGA device database
//!
//! `dse`, `fit-fleet` and `sweep` accept `--cache-file F`: the estimator
//! memo is seeded from F when it exists (corrupt or stale files warn and
//! start cold) and written back on success, so repeat explorations across
//! processes start warm.

use anyhow::{anyhow, bail, Result};

use cnn2gate::cli::Args;
use cnn2gate::coordinator::{pipeline, InferenceServer, ServerConfig};
use cnn2gate::dse::{brute, eval, rl, EvalCache, Evaluator, Fidelity, RlConfig};
use cnn2gate::estimator::{device, estimate, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::{
    baselines, comparison_table, fig6, fleet_table, stepped_census_table,
    sweep_best_device_table, sweep_best_model_table, sweep_pareto_table, sweep_table, table1,
    table2,
};
use cnn2gate::runtime::{load_golden, Manifest, Tensor};
use cnn2gate::sim::simulate;
use cnn2gate::synth::{self, Explorer};
use cnn2gate::util::rng::Rng;
use cnn2gate::util::table::fmt_duration;

const USAGE: &str = "\
cnn2gate — CNN2Gate reproduction (Rust + JAX + Pallas)

USAGE:
  cnn2gate info      --model <zoo|file.json>
  cnn2gate dse       --model <m> --device <d> [--explorer rl|bf] [--seed N]
                     [--fidelity analytical|stepped|stepped-full]
                     [--threads N] [--seq] [--cache-file F]
                     [--cache-max-entries N]
  cnn2gate fit-fleet --model <m> [--explorer rl|bf] [--threads N]
                     [--cache-file F] [--cache-max-entries N]
  cnn2gate sweep     [--models m1,m2,...] [--explorer rl|bf] [--threads N]
                     [--fidelity analytical|stepped|stepped-full]
                     [--cache-file F] [--cache-max-entries N]
  cnn2gate synth     --model <m> --device <d> [--explorer rl|bf] [--quantize]
                     [--report]
  cnn2gate emulate   --model <m> [--artifacts DIR]
  cnn2gate serve     --model <m> [--artifacts DIR] [--requests N] [--batch B]
  cnn2gate tables    [--artifacts DIR]
  cnn2gate devices

MODELS: tiny lenet5 alexnet vgg16 (or a cnn2gate-onnx-subset .json file)
DEVICES: 5csema4 5csema5 arria10 stratixv

`--fidelity stepped` runs the cycle-accurate simulator on each candidate's
dominant round; `stepped-full` steps every round (epoch skip-ahead engine).
`synth --report` prints the chosen design's per-layer stall/backpressure
census. `--cache-max-entries N` LRU-evicts the --cache-file before saving.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn thresholds_from(args: &Args) -> Result<Thresholds> {
    Ok(Thresholds {
        lut: args.get_f64("max-lut", 101.0)?,
        dsp: args.get_f64("max-dsp", 101.0)?,
        mem: args.get_f64("max-mem", 101.0)?,
        reg: args.get_f64("max-reg", 101.0)?,
    })
}

fn explorer_from(args: &Args) -> Result<Explorer> {
    match args.get_or("explorer", "rl") {
        "rl" => Ok(Explorer::Reinforcement),
        "bf" => Ok(Explorer::BruteForce),
        other => bail!("--explorer must be rl or bf, got '{other}'"),
    }
}

fn fidelity_from(args: &Args) -> Result<Fidelity> {
    Ok(
        match args.get_choice(
            "fidelity",
            &["analytical", "stepped", "stepped-full"],
            "analytical",
        )? {
            "stepped" => Fidelity::SteppedDominantRound,
            "stepped-full" => Fidelity::SteppedFullNetwork,
            _ => Fidelity::Analytical,
        },
    )
}

fn dispatch(argv: &[String]) -> Result<()> {
    let flags = [
        "model", "models", "device", "explorer", "fidelity", "artifacts", "requests", "batch",
        "seed", "threads", "cache-file", "cache-max-entries", "max-lut", "max-dsp", "max-mem",
        "max-reg",
    ];
    let switches = ["quantize", "verbose", "seq", "report"];
    let args = Args::parse(argv, &flags, &switches)?;
    match args.subcommand.as_str() {
        "info" => cmd_info(&args),
        "dse" => cmd_dse(&args),
        "fit-fleet" => cmd_fit_fleet(&args),
        "sweep" => cmd_sweep(&args),
        "synth" => cmd_synth(&args),
        "emulate" => cmd_emulate(&args),
        "serve" => cmd_serve(&args),
        "tables" => cmd_tables(&args),
        "devices" => cmd_devices(),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
}

/// The evaluator a subcommand scores candidates through, plus the
/// optional `--cache-file` it persists the memo back to.
///
/// With `--cache-file F` the session gets a private evaluator whose memo
/// is seeded from F (tolerantly: a missing file starts cold silently, a
/// corrupt or stale one warns and starts cold — it is never trusted).
/// With only `--threads N` the pool is private but the memo starts cold;
/// with neither, the process-global evaluator is shared.
struct EvalSession {
    evaluator: Option<Evaluator>,
    cache_file: Option<std::path::PathBuf>,
    /// `--cache-max-entries`: LRU-evict down to this before saving
    /// (0 = unlimited).
    cache_max_entries: usize,
}

impl EvalSession {
    fn open(args: &Args) -> Result<EvalSession> {
        let threads = args.get_usize("threads", 0)?;
        let cache_file = args.get("cache-file").map(std::path::PathBuf::from);
        let cache_max_entries = args.get_usize("cache-max-entries", 0)?;
        let evaluator = match (&cache_file, threads) {
            (None, 0) => None,
            (None, n) => Some(Evaluator::new(n)),
            (Some(path), n) => {
                let (cache, warning) = EvalCache::load_or_cold(path);
                if let Some(w) = warning {
                    eprintln!("warning: {w}");
                }
                let n = if n == 0 { eval::default_threads() } else { n };
                Some(Evaluator::with_cache(n, std::sync::Arc::new(cache)))
            }
        };
        Ok(EvalSession {
            evaluator,
            cache_file,
            cache_max_entries,
        })
    }

    fn evaluator(&self) -> &Evaluator {
        match &self.evaluator {
            Some(ev) => ev,
            None => eval::global(),
        }
    }

    /// Persist the memo back to `--cache-file`, when one was given,
    /// LRU-evicting first when `--cache-max-entries` bounds the file.
    fn close(&self) -> Result<()> {
        if let Some(path) = &self.cache_file {
            if self.cache_max_entries > 0 {
                let evicted = self.evaluator().cache().evict_lru(self.cache_max_entries);
                if evicted > 0 {
                    println!(
                        "cache: evicted {evicted} least-recently-used entries (--cache-max-entries {})",
                        self.cache_max_entries
                    );
                }
            }
            let written = self.evaluator().cache().save(path)?;
            println!("cache: {written} entries saved to {}", path.display());
        }
        Ok(())
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let g = pipeline::load_model(model, false)?;
    let flow = ComputationFlow::extract(&g).map_err(|e| anyhow!("{e}"))?;
    println!("model: {} (input {:?})", g.name, g.input.shape);
    println!(
        "params: {:.2} M   ops: {:.2} GOp/frame   rounds: {} conv + {} fc",
        g.param_count() as f64 / 1e6,
        flow.gops(),
        flow.conv_rounds(),
        flow.fc_rounds()
    );
    for l in &flow.layers {
        println!(
            "  round {:>2}: {:<9} red={:<6} out_f={:<5} pixels={:<6} macs={:.1} M",
            l.index + 1,
            if l.is_conv() { "conv/pool" } else { "fc" },
            l.reduction_dim(),
            l.out_features(),
            l.out_pixels(),
            l.macs() as f64 / 1e6
        );
    }
    let space = cnn2gate::dse::OptionSpace::from_flow(&flow);
    println!("option space: Ni {:?} x Nl {:?}", space.ni, space.nl);
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dev = pipeline::load_device(args.get("device").unwrap_or("arria10"))?;
    let g = pipeline::load_model(model, false)?;
    let flow = ComputationFlow::extract(&g).map_err(|e| anyhow!("{e}"))?;
    let th = thresholds_from(args)?;
    // --cache-file / --threads build a private (possibly disk-seeded)
    // evaluator; the default shares the global pool + memo; --seq forces
    // the sequential seed path (baseline, bypasses the cache).
    let fidelity = fidelity_from(args)?;
    let session = EvalSession::open(args)?;
    let evaluator = session.evaluator();
    let result = match explorer_from(args)? {
        Explorer::BruteForce if args.has("seq") => {
            if fidelity != Fidelity::Analytical {
                bail!("--seq is the analytical seed path; drop --seq to use --fidelity");
            }
            brute::explore_seq(&flow, dev, th)
        }
        Explorer::Reinforcement if args.has("seq") => {
            bail!("--seq applies to the brute-force explorer (use --explorer bf); RL is inherently sequential")
        }
        Explorer::BruteForce => brute::explore_with_fidelity(evaluator, &flow, dev, th, fidelity),
        Explorer::Reinforcement => {
            let cfg = RlConfig {
                seed: args.get_usize("seed", 0xD5E)? as u64,
                ..RlConfig::default()
            };
            rl::explore_with_fidelity(evaluator, &flow, dev, th, cfg, fidelity)
        }
    };
    println!("device: {}", dev.name);
    match result.best {
        Some((ni, nl)) => println!("H_best = ({ni},{nl})  F_max = {:.2}%", result.f_max),
        None => println!("Does not fit"),
    }
    println!(
        "queries: {} ({} cached)   wall: {}   modeled (Intel compiler scale): {}",
        result.queries,
        result.cache_hits,
        fmt_duration(result.wall_seconds),
        fmt_duration(result.modeled_seconds)
    );
    for (ni, nl, favg, feasible) in &result.trace {
        println!(
            "  ({ni:>2},{nl:>2})  F_avg {favg:>6.2}%  {}",
            if *feasible { "fits" } else { "over budget" }
        );
    }
    session.close()
}

fn cmd_fit_fleet(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let g = pipeline::load_model(model, false)?;
    let session = EvalSession::open(args)?;
    let rep = pipeline::fit_fleet_with(
        session.evaluator(),
        &g,
        explorer_from(args)?,
        thresholds_from(args)?,
    )?;
    println!("{}", fleet_table(&rep.model, &rep.entries).render());
    match rep.best() {
        Some(best) => match (best.option(), best.latency_ms()) {
            (Some((ni, nl)), Some(ms)) => println!(
                "recommended: {} at ({ni},{nl}) — {ms:.2} ms simulated latency",
                best.device
            ),
            _ => println!("recommended: {}", best.device),
        },
        None => println!("recommended: none — {model} fits no device in the database"),
    }
    let stats = session.evaluator().cache().stats();
    println!(
        "fleet wall: {}   estimator memo: {} entries, {} hits / {} misses",
        fmt_duration(rep.wall_seconds),
        stats.entries,
        stats.hits,
        stats.misses
    );
    session.close()
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let names = args.get_list("models", &["alexnet", "vgg16"]);
    let mut graphs = Vec::with_capacity(names.len());
    for name in &names {
        graphs.push(pipeline::load_model(name, false)?);
    }
    let session = EvalSession::open(args)?;
    let rep = pipeline::sweep_matrix_with(
        session.evaluator(),
        &graphs,
        explorer_from(args)?,
        thresholds_from(args)?,
        fidelity_from(args)?,
    )?;
    println!("{}", sweep_table(&rep).render());
    println!("{}", sweep_best_device_table(&rep).render());
    println!("{}", sweep_best_model_table(&rep).render());
    println!("{}", sweep_pareto_table(&rep).render());
    let stats = session.evaluator().cache().stats();
    println!(
        "sweep wall: {}   estimator memo: {} entries, {} hits / {} misses",
        fmt_duration(rep.wall_seconds),
        stats.entries,
        stats.hits,
        stats.misses
    );
    session.close()
}

fn cmd_synth(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dev = pipeline::load_device(args.get("device").unwrap_or("arria10"))?;
    let quantize = args.has("quantize");
    let g = pipeline::load_model(model, quantize)?;
    let spec = cnn2gate::quant::QuantSpec::default();
    // --report upgrades the flow to full-network stepped fidelity so the
    // chosen design carries its per-layer stall/backpressure census
    let fidelity = if args.has("report") {
        Fidelity::SteppedFullNetwork
    } else {
        Fidelity::Analytical
    };
    let rep = synth::run_with_fidelity(
        eval::global(),
        &g,
        dev,
        explorer_from(args)?,
        thresholds_from(args)?,
        (quantize && g.has_weights()).then_some(&spec),
        fidelity,
    )?;
    println!("model: {}   device: {}", rep.model, rep.device);
    match (&rep.estimate, &rep.sim) {
        (Some(est), Some(sim)) => {
            println!(
                "H_best = ({},{})   fmax = {:.0} MHz   synthesis ≈ {}",
                est.ni,
                est.nl,
                est.fmax_mhz,
                fmt_duration(rep.synthesis_minutes.unwrap_or(0.0) * 60.0)
            );
            println!(
                "resources: ALM {:.0} ({:.0}%)  DSP {:.0} ({:.0}%)  RAM {:.0} ({:.0}%)  regs ({:.0}%)",
                est.alms, est.p_lut, est.dsps, est.p_dsp, est.ram_blocks, est.p_mem, est.p_reg
            );
            println!("{}", fig6(sim).render());
            let gops = metrics::gops_per_s(sim.gops, sim.total_millis);
            println!(
                "latency {:.2} ms   throughput {gops:.1} GOp/s   density {:.3} GOp/s/DSP   efficiency {:.0}% of lane peak",
                sim.total_millis,
                metrics::gops_per_dsp(gops, est.dsps),
                100.0 * sim.efficiency()
            );
            if let Some(net) = &rep.stepped_network {
                println!("{}", stepped_census_table(sim, net).render());
            }
        }
        _ => println!("Does not fit on {}", rep.device),
    }
    if args.has("report") && !rep.fits() {
        println!("(no stepped census: the design does not fit)");
    }
    if let Some(q) = &rep.quant {
        println!(
            "quantization: {} tensors, worst |err| {:.4}, worst saturation {:.2}%",
            q.tensors.len(),
            q.worst_abs_err(),
            100.0 * q.worst_sat_ratio()
        );
    }
    Ok(())
}

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    args.get_or("artifacts", "artifacts").into()
}

fn cmd_emulate(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dir = artifacts_dir(args);
    match pipeline::run_emulation(&dir, model)? {
        Some(res) => {
            println!(
                "emulation {} OK: PJRT exec {}   golden max |err| = {:.3e}",
                res.model,
                fmt_duration(res.exec_seconds),
                res.golden_max_err.unwrap_or(f64::NAN)
            );
            Ok(())
        }
        None => {
            // no golden: time with synthetic weights instead (Table 1's
            // emulation column for the big models)
            let manifest = Manifest::load(&dir)?;
            let art = manifest
                .model(model)
                .ok_or_else(|| anyhow!("model '{model}' not in {}", dir.display()))?;
            let seconds = pipeline::time_emulation_synthetic(art, 1)?;
            println!(
                "emulation {model}: {} per frame (synthetic weights)",
                fmt_duration(seconds)
            );
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("lenet5");
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let art = manifest
        .model(model)
        .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?;
    let weights = match &art.golden {
        Some(g) => load_golden(g)?.params,
        None => pipeline::synthetic_weights(art, 7),
    };
    let n = args.get_usize("requests", 32)?;
    let cfg = ServerConfig {
        max_batch: args.get_usize("batch", 8)?,
        ..ServerConfig::default()
    };
    let server = InferenceServer::start(art, weights, cfg)?;
    let mut rng = Rng::new(11);
    let numel: usize = art.input.shape.iter().product();
    for _ in 0..n {
        let input = match server.out_dtype() {
            cnn2gate::ir::DType::F32 => {
                Tensor::F32(art.input.shape.clone(), rng.tensor_f32(numel))
            }
            _ => Tensor::I32(
                art.input.shape.clone(),
                (0..numel).map(|_| rng.range_i64(-128, 127) as i32).collect(),
            ),
        };
        server.infer(input)?;
    }
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches: exec p50 {:.2} ms p99 {:.2} ms | e2e p50 {:.2} ms p99 {:.2} ms",
        stats.served,
        stats.batches,
        stats.exec.p50_ms,
        stats.exec.p99_ms,
        stats.e2e.p50_ms,
        stats.e2e.p99_ms
    );
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    let alex = zoo::build("alexnet", false).ok_or_else(|| anyhow!("zoo model 'alexnet' missing"))?;
    let vgg = zoo::build("vgg16", false).ok_or_else(|| anyhow!("zoo model 'vgg16' missing"))?;
    let aflow = ComputationFlow::extract(&alex).map_err(|e| anyhow!("{e}"))?;
    let vflow = ComputationFlow::extract(&vgg).map_err(|e| anyhow!("{e}"))?;
    let th = Thresholds::default();

    // Table 1 (the CPU row needs a real PJRT backend — skipped on stub builds)
    let mut rows = Vec::new();
    let dir = artifacts_dir(args);
    if cnn2gate::runtime::Runtime::available() {
        if let Ok(manifest) = Manifest::load(&dir) {
            let a = manifest
                .model("alexnet")
                .map(|art| pipeline::time_emulation_synthetic(art, 1))
                .transpose()?;
            let v = manifest
                .model("vgg16")
                .map(|art| pipeline::time_emulation_synthetic(art, 1))
                .transpose()?;
            rows.push((
                "CPU (PJRT emulation)".to_string(),
                "N/A".to_string(),
                a.map(|s| s * 1e3),
                v.map(|s| s * 1e3),
                None,
            ));
        }
    }
    for (dev, ni, nl) in [(&CYCLONE_V_5CSEMA5, 8, 8), (&ARRIA_10_GX1150, 16, 32)] {
        let est = estimate(&aflow, dev, ni, nl);
        let asim = simulate(&aflow, dev, ni, nl);
        let vsim = simulate(&vflow, dev, ni, nl);
        rows.push((
            dev.name.to_string(),
            format!(
                "Logic {:.0}% DSP {:.0}% RAM {:.0}%",
                est.p_lut, est.p_dsp, est.p_mem
            ),
            Some(asim.total_millis),
            Some(vsim.total_millis),
            Some(est.fmax_mhz),
        ));
    }
    println!("{}", table1(&rows).render());

    // Table 2
    let mut reports = Vec::new();
    for dev in [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150] {
        let rep = synth::run(&alex, dev, Explorer::BruteForce, th, None)?;
        let rl_res = rl::explore(&aflow, dev, th, RlConfig::default());
        let bf_res = brute::explore(&aflow, dev, th);
        reports.push((rep, rl_res, bf_res));
    }
    let refs: Vec<_> = reports.iter().map(|(a, b, c)| (a, b, c)).collect();
    println!("{}", table2(&refs).render());

    // Tables 3 + 4
    let est = estimate(&aflow, &ARRIA_10_GX1150, 16, 32);
    let asim = simulate(&aflow, &ARRIA_10_GX1150, 16, 32);
    println!(
        "{}",
        comparison_table(
            "Table 3: Comparison to existing works, AlexNet (Ni,Nl)=(16,32)",
            &baselines::alexnet(),
            &asim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );
    let vsim = simulate(&vflow, &ARRIA_10_GX1150, 16, 32);
    println!(
        "{}",
        comparison_table(
            "Table 4: Comparison to existing works, VGG-16 (Ni,Nl)=(16,32)",
            &baselines::vgg16(),
            &vsim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );

    // Fig 6
    println!("{}", fig6(&asim).render());
    Ok(())
}

fn cmd_devices() -> Result<()> {
    for d in device::all() {
        println!(
            "{:<24} family {:?}  ALM {}  DSP {}  RAM blocks {}  mem {} bits  base {} MHz",
            d.name, d.family, d.alms, d.dsps, d.ram_blocks, d.mem_bits, d.base_clock_mhz
        );
    }
    Ok(())
}
