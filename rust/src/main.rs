//! `cnn2gate` — leader entrypoint + CLI.
//!
//! Subcommands mirror the paper's workflow (Fig. 4a):
//!   info      parse a model, print the extracted computation flow
//!   dse       design-space exploration on a device (RL or brute force)
//!   fit-fleet fit one model on every device in the database, in parallel
//!   sweep     explore every (model, device) pair: rankings + Pareto frontier
//!   synth     full (simulated) synthesis flow: DSE + fit + latency
//!   emulate   emulation mode: run the AOT artifacts through PJRT
//!   serve     compile-service daemon demo: compile jobs + inference lane
//!   tables    regenerate the paper's Tables 1-4 + Fig. 6
//!   devices   list the FPGA device database
//!
//! Every subcommand is declared once in [`SUBCOMMANDS`]: its flag
//! allowlist, its switches and its USAGE line all derive from the same
//! registry entry, so help text can't drift from what actually parses.
//! The `synth`/`fit-fleet`/`sweep` flows are thin adapters over
//! [`cnn2gate::session`]: flags build a [`Session`] + [`CompileJob`],
//! `session.run(&job)` does the work, and `--json` renders the
//! [`Outcome`](cnn2gate::session::Outcome) as a stable machine-readable
//! document instead of tables.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use cnn2gate::cli::Args;
use cnn2gate::coordinator::service::{Event, JobState};
use cnn2gate::coordinator::{pipeline, CompileService, JobSpec, ServiceConfig};
use cnn2gate::dse::{brute, rl, EvalCache, Fidelity, RlConfig};
use cnn2gate::estimator::{device, estimate};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::quant::QuantSpec;
use cnn2gate::report::{
    baselines, comparison_table, fig6, fig6_specialized, fleet_table, specialization_table,
    stepped_census_table, sweep_best_device_table, sweep_best_model_table, sweep_pareto_table,
    sweep_table, sweep_throughput_table, table1, table2,
};
use cnn2gate::runtime::{load_golden, Manifest, Tensor};
use cnn2gate::session::{CompileJob, Session, SessionBuilder};
use cnn2gate::sim::simulate;
use cnn2gate::synth::Explorer;
use cnn2gate::util::rng::Rng;
use cnn2gate::util::table::fmt_duration;

// ---------------------------------------------------------------------------
// Declarative subcommand registry: one entry per subcommand drives the
// parser allowlist AND the generated USAGE text.
// ---------------------------------------------------------------------------

/// A value-taking flag: `--name <value>`.
struct FlagSpec {
    name: &'static str,
    /// Placeholder shown in USAGE (e.g. `<m>`, `rl|bf`).
    value: &'static str,
    /// Required flags render bare; optional ones render in brackets.
    required: bool,
}

const fn req(name: &'static str, value: &'static str) -> FlagSpec {
    FlagSpec {
        name,
        value,
        required: true,
    }
}

const fn opt(name: &'static str, value: &'static str) -> FlagSpec {
    FlagSpec {
        name,
        value,
        required: false,
    }
}

struct Subcommand {
    name: &'static str,
    flags: &'static [FlagSpec],
    switches: &'static [&'static str],
    run: fn(&Args) -> Result<()>,
}

static SUBCOMMANDS: &[Subcommand] = &[
    Subcommand {
        name: "info",
        flags: &[req("model", "<zoo|file.json>")],
        switches: &[],
        run: cmd_info,
    },
    Subcommand {
        name: "dse",
        flags: &[
            req("model", "<m>"),
            opt("device", "<d>"),
            opt("explorer", "rl|bf"),
            opt("fidelity", "analytical|stepped|stepped-full"),
            opt("census-gamma", "<g>"),
            opt("seed", "N"),
            opt("threads", "N"),
            opt("cache-dir", "D"),
            opt("cache-file", "F"),
            opt("cache-max-entries", "N"),
            opt("max-lut", "<pct>"),
            opt("max-dsp", "<pct>"),
            opt("max-mem", "<pct>"),
            opt("max-reg", "<pct>"),
        ],
        switches: &["seq"],
        run: cmd_dse,
    },
    Subcommand {
        name: "fit-fleet",
        flags: &[
            req("model", "<m>"),
            opt("explorer", "rl|bf"),
            opt("fidelity", "analytical|stepped|stepped-full"),
            opt("census-gamma", "<g>"),
            opt("batch", "b1,b2,..."),
            opt("latency-slo", "<ms>"),
            opt("threads", "N"),
            opt("cache-dir", "D"),
            opt("cache-file", "F"),
            opt("cache-max-entries", "N"),
            opt("max-lut", "<pct>"),
            opt("max-dsp", "<pct>"),
            opt("max-mem", "<pct>"),
            opt("max-reg", "<pct>"),
        ],
        switches: &["json"],
        run: cmd_fit_fleet,
    },
    Subcommand {
        name: "sweep",
        flags: &[
            opt("models", "m1,m2,..."),
            opt("explorer", "rl|bf"),
            opt("fidelity", "analytical|stepped|stepped-full"),
            opt("census-gamma", "<g>"),
            opt("batch", "b1,b2,..."),
            opt("latency-slo", "<ms>"),
            opt("threads", "N"),
            opt("cache-dir", "D"),
            opt("cache-file", "F"),
            opt("cache-max-entries", "N"),
            opt("max-lut", "<pct>"),
            opt("max-dsp", "<pct>"),
            opt("max-mem", "<pct>"),
            opt("max-reg", "<pct>"),
        ],
        switches: &["json"],
        run: cmd_sweep,
    },
    Subcommand {
        name: "synth",
        flags: &[
            req("model", "<m>"),
            opt("device", "<d>"),
            opt("explorer", "rl|bf"),
            opt("census-gamma", "<g>"),
            opt("batch", "b1,b2,..."),
            opt("latency-slo", "<ms>"),
            opt("threads", "N"),
            opt("cache-dir", "D"),
            opt("cache-file", "F"),
            opt("cache-max-entries", "N"),
            opt("max-lut", "<pct>"),
            opt("max-dsp", "<pct>"),
            opt("max-mem", "<pct>"),
            opt("max-reg", "<pct>"),
        ],
        switches: &["quantize", "report", "specialize", "json"],
        run: cmd_synth,
    },
    Subcommand {
        name: "emulate",
        flags: &[req("model", "<m>"), opt("artifacts", "DIR")],
        switches: &[],
        run: cmd_emulate,
    },
    Subcommand {
        name: "serve",
        flags: &[
            opt("model", "<m>"),
            opt("device", "<d>"),
            opt("artifacts", "DIR"),
            opt("requests", "N"),
            opt("batch", "B"),
            opt("latency-slo", "<ms>"),
            opt("workers", "N"),
            opt("queue", "N"),
            opt("threads", "N"),
            opt("cache-dir", "D"),
            opt("cache-file", "F"),
            opt("cache-max-entries", "N"),
            opt("compile-models", "m1,m2,..."),
        ],
        switches: &[],
        run: cmd_serve,
    },
    Subcommand {
        name: "tables",
        flags: &[opt("artifacts", "DIR")],
        switches: &[],
        run: cmd_tables,
    },
    Subcommand {
        name: "devices",
        flags: &[],
        switches: &[],
        run: cmd_devices,
    },
];

const USAGE_FOOTER: &str = "\
MODELS: tiny lenet5 alexnet vgg16 (or a cnn2gate-onnx-subset .json file)
DEVICES: 5csema4 5csema5 arria10 stratixv

Flags accept both `--flag value` and `--flag=value`. `--fidelity stepped`
runs the cycle-accurate simulator on each candidate's dominant round;
`stepped-full` steps every round (epoch skip-ahead engine). `synth
--report` prints the chosen design's per-layer stall/backpressure census;
`synth --specialize` additionally re-folds each round to its own (Ni,Nl)
and weight schedule (both switches imply stepped-full fidelity).
`--census-gamma g` shapes every explorer reward with the stepped
census's bottleneck stall fraction (0 = the paper's Algorithm 1; the
stall term is live under stepped-full fidelity). `--cache-dir D`
persists the evaluation memo as a sharded append-only store (one shard
per (tenant, model), delta logs + compaction, advisory-locked for
concurrent writers); `--cache-file F` is the legacy single-file cache —
still loaded (and migrated into the store when both are given), but the
store is the recommended persistence. `--cache-max-entries N` LRU-evicts
the memo before saving. `--json` on synth/fit-fleet/sweep emits the
stable machine-readable outcome document instead of tables.
`--batch b1,b2,...` on synth/fit-fleet/sweep runs the (Ni,Nl,B)
throughput co-optimization: the explorer re-runs per batch size (weights
fetched once per group pass, held across the B frames) and the
highest-frames/s batch whose end-to-end latency — queueing delay plus
batch makespan — meets `--latency-slo <ms>` wins; sweep prints a
frames/s ranking table for the explored batches. `serve` runs the
in-process compile-service daemon: `--compile-models m1,m2` submits
fleet compile jobs that stream typed admission/progress events
(`--workers`/`--queue` bound concurrency and admission), while
`--requests N` inferences ride the same daemon's batched emulation lane
when PJRT artifacts exist. Without `serve --batch B` the inference
micro-batch cap is sized by the throughput DSE of the served model on
`--device` (under `--latency-slo` when given); the daemon's compile
jobs share the session memo, so `serve --cache-dir D` both seeds the
daemon from earlier sweeps and persists what it computes.
";

/// The USAGE text, generated from [`SUBCOMMANDS`] so it cannot drift
/// from the flags the parser actually accepts.
fn usage() -> String {
    let mut out =
        String::from("cnn2gate — CNN2Gate reproduction (Rust + JAX + Pallas)\n\nUSAGE:\n");
    for cmd in SUBCOMMANDS {
        let prefix = format!("  cnn2gate {:<9}", cmd.name);
        let indent = " ".repeat(prefix.len() + 1);
        let mut tokens: Vec<String> = Vec::new();
        for f in cmd.flags {
            let t = format!("--{} {}", f.name, f.value);
            tokens.push(if f.required { t } else { format!("[{t}]") });
        }
        for s in cmd.switches {
            tokens.push(format!("[--{s}]"));
        }
        let mut line = prefix;
        for (i, t) in tokens.iter().enumerate() {
            if i > 0 && line.len() + 1 + t.len() > 78 {
                out.push_str(line.trim_end());
                out.push('\n');
                line = indent.clone();
            }
            line.push(' ');
            line.push_str(t);
        }
        out.push_str(line.trim_end());
        out.push('\n');
    }
    out.push('\n');
    out.push_str(USAGE_FOOTER);
    out
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", usage());
        return;
    }
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = SUBCOMMANDS.iter().find(|c| c.name == argv[0]) else {
        bail!("unknown subcommand '{}'\n\n{}", argv[0], usage());
    };
    let flags: Vec<&str> = cmd.flags.iter().map(|f| f.name).collect();
    let args = Args::parse(argv, &flags, cmd.switches)?;
    (cmd.run)(&args)
}

// ---------------------------------------------------------------------------
// Session plumbing shared by the compile-flow subcommands
// ---------------------------------------------------------------------------

/// Build the session every compile-flow subcommand runs through, from
/// the same flags ([`SessionBuilder::from_args`]), surfacing any cache
/// load warning on stderr. `fidelity` overrides the flag-derived value
/// (the `synth --report` upgrade).
fn open_session_at(args: &Args, fidelity: Option<Fidelity>) -> Result<Session> {
    let mut builder = SessionBuilder::from_args(args)?;
    if let Some(f) = fidelity {
        builder = builder.fidelity(f);
    }
    let session = builder.build();
    if let Some(w) = session.load_warning() {
        eprintln!("warning: {w}");
    }
    Ok(session)
}

fn open_session(args: &Args) -> Result<Session> {
    open_session_at(args, None)
}

/// Persist the session memo per its cache policy. `json` routes the
/// human-readable notes to stderr so `--json` keeps stdout parseable.
fn close_session(session: &Session, json: bool) -> Result<()> {
    let save = session.close()?;
    let note = |msg: String| {
        if json {
            eprintln!("{msg}");
        } else {
            println!("{msg}");
        }
    };
    if save.evicted > 0 {
        note(format!(
            "cache: evicted {} least-recently-used entries (--cache-max-entries {})",
            save.evicted,
            session.cache_policy().max_entries
        ));
    }
    if let Some((saved, dir)) = save.store {
        note(format!(
            "cache store: {} entries in {} ({} shards touched: {} appended, {} tombstones, {} rewritten, {} compacted)",
            saved.entries,
            dir.display(),
            saved.shards_written,
            saved.appended,
            saved.tombstones,
            saved.rewritten,
            saved.compacted
        ));
    }
    if let Some((written, path)) = save.written {
        note(format!("cache: {written} entries saved to {}", path.display()));
    }
    Ok(())
}

/// Apply the throughput-mode flags (`--batch`, `--latency-slo`) to a
/// job builder — shared by synth, fit-fleet and sweep.
fn throughput_flags(
    mut builder: cnn2gate::session::CompileJobBuilder,
    args: &Args,
) -> Result<cnn2gate::session::CompileJobBuilder> {
    builder = builder.batches(CompileJob::batches_from_args(args)?);
    if let Some(ms) = CompileJob::latency_slo_from_args(args)? {
        builder = builder.latency_slo_ms(ms);
    }
    Ok(builder)
}

/// One human-readable line for a report's throughput choice, when the
/// job ran in throughput mode.
fn throughput_line(rep: &cnn2gate::synth::SynthReport) -> Option<String> {
    let choice = rep.throughput.as_ref()?;
    let c = choice.chosen_candidate()?;
    let slo = match (choice.latency_slo_ms, choice.slo_satisfied) {
        (Some(ms), true) => format!(" (meets {ms:.1} ms SLO)"),
        (Some(ms), false) => format!(" (MISSES {ms:.1} ms SLO — best effort)"),
        (None, _) => String::new(),
    };
    Some(format!(
        "throughput: batch {} — {:.1} frames/s, {:.2} ms batch makespan, {:.2} ms end-to-end{slo}",
        c.batch, c.frames_per_s, c.batch_millis, c.e2e_millis
    ))
}

fn scheduler_line(outcome: &cnn2gate::session::Outcome) -> String {
    format!(
        "scheduler: {} items, {} steals on {} workers",
        outcome.steals.executed, outcome.steals.steals, outcome.steals.workers
    )
}

// ---------------------------------------------------------------------------
// Subcommands
// ---------------------------------------------------------------------------

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let g = pipeline::load_model(model, false)?;
    let flow = ComputationFlow::extract(&g).map_err(|e| anyhow!("{e}"))?;
    println!("model: {} (input {:?})", g.name, g.input.shape);
    println!(
        "params: {:.2} M   ops: {:.2} GOp/frame   rounds: {} conv + {} fc",
        g.param_count() as f64 / 1e6,
        flow.gops(),
        flow.conv_rounds(),
        flow.fc_rounds()
    );
    for l in &flow.layers {
        println!(
            "  round {:>2}: {:<9} red={:<6} out_f={:<5} pixels={:<6} macs={:.1} M",
            l.index + 1,
            if l.is_conv() { "conv/pool" } else { "fc" },
            l.reduction_dim(),
            l.out_features(),
            l.out_pixels(),
            l.macs() as f64 / 1e6
        );
    }
    let space = cnn2gate::dse::OptionSpace::from_flow(&flow);
    println!("option space: Ni {:?} x Nl {:?}", space.ni, space.nl);
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dev = pipeline::load_device(args.get("device").unwrap_or("arria10"))?;
    let g = pipeline::load_model(model, false)?;
    let flow = ComputationFlow::extract(&g).map_err(|e| anyhow!("{e}"))?;
    // --cache-file / --threads build a private (possibly disk-seeded)
    // evaluator; the default shares the global pool + memo; --seq forces
    // the sequential seed path (baseline, bypasses the cache).
    let session = open_session(args)?;
    let th = session.thresholds();
    let req = session.request();
    let evaluator = session.evaluator();
    let result = match CompileJob::explorer_from_args(args)? {
        Explorer::BruteForce if args.has("seq") => {
            if req.fidelity != Fidelity::Analytical {
                bail!("--seq is the analytical seed path; drop --seq to use --fidelity");
            }
            // analysis: allow(float-eq, γ = 0.0 is the exact unshaped default, not a computed value)
            if req.census_gamma != 0.0 {
                bail!("--seq is the plain Algorithm-1 seed path; drop --seq to use --census-gamma");
            }
            brute::explore_seq(&flow, dev, th)
        }
        Explorer::Reinforcement if args.has("seq") => {
            bail!("--seq applies to the brute-force explorer (use --explorer bf); RL is inherently sequential")
        }
        Explorer::BruteForce => brute::explore_with_fidelity(evaluator, &flow, dev, th, req),
        Explorer::Reinforcement => {
            let cfg = RlConfig {
                seed: args.get_usize("seed", 0xD5E)? as u64,
                ..RlConfig::default()
            };
            rl::explore_with_fidelity(evaluator, &flow, dev, th, cfg, req)
        }
    };
    println!("device: {}", dev.name);
    match result.best {
        Some((ni, nl)) => println!("H_best = ({ni},{nl})  F_max = {:.2}%", result.f_max),
        None => println!("Does not fit"),
    }
    println!(
        "queries: {} ({} cached)   wall: {}   modeled (Intel compiler scale): {}",
        result.queries,
        result.cache_hits,
        fmt_duration(result.wall_seconds),
        fmt_duration(result.modeled_seconds)
    );
    for (ni, nl, favg, feasible) in &result.trace {
        println!(
            "  ({ni:>2},{nl:>2})  F_avg {favg:>6.2}%  {}",
            if *feasible { "fits" } else { "over budget" }
        );
    }
    close_session(&session, false)
}

fn cmd_fit_fleet(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let g = pipeline::load_model(model, false)?;
    let session = open_session(args)?;
    let builder = CompileJob::builder()
        .model(g)
        .all_devices()
        .explorer(CompileJob::explorer_from_args(args)?);
    let job = throughput_flags(builder, args)?.build()?;
    let outcome = session.run(&job)?;
    let json = args.has("json");
    if json {
        print!("{}", outcome.to_json().to_string_pretty());
    } else {
        let rep = outcome
            .to_fleet_report()
            .ok_or_else(|| anyhow!("fit-fleet outcome rendered no fleet view for {model}"))?;
        println!("{}", fleet_table(&rep.model, &rep.entries).render());
        match rep.best() {
            Some(best) => match (best.option(), best.latency_ms()) {
                (Some((ni, nl)), Some(ms)) => println!(
                    "recommended: {} at ({ni},{nl}) — {ms:.2} ms simulated latency",
                    best.device
                ),
                _ => println!("recommended: {}", best.device),
            },
            None => println!("recommended: none — {model} fits no device in the database"),
        }
        for entry in &rep.entries {
            if let Some(line) = throughput_line(entry) {
                println!("{}: {line}", entry.device);
            }
        }
        let stats = outcome.cache;
        println!(
            "fleet wall: {}   estimator memo: {} entries, {} hits / {} misses   {}",
            fmt_duration(outcome.wall_seconds),
            stats.entries,
            stats.hits,
            stats.misses,
            scheduler_line(&outcome)
        );
    }
    close_session(&session, json)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let names = args.get_list("models", &["alexnet", "vgg16"]);
    let mut graphs = Vec::with_capacity(names.len());
    for name in &names {
        graphs.push(pipeline::load_model(name, false)?);
    }
    let session = open_session(args)?;
    let builder = CompileJob::builder()
        .models(graphs)
        .all_devices()
        .explorer(CompileJob::explorer_from_args(args)?);
    let job = throughput_flags(builder, args)?.build()?;
    let outcome = session.run(&job)?;
    let json = args.has("json");
    if json {
        print!("{}", outcome.to_json().to_string_pretty());
    } else {
        let rep = outcome.to_sweep_report();
        println!("{}", sweep_table(&rep).render());
        if rep.entries.iter().any(|e| e.throughput.is_some()) {
            println!("{}", sweep_throughput_table(&rep).render());
        }
        println!("{}", sweep_best_device_table(&rep).render());
        println!("{}", sweep_best_model_table(&rep).render());
        println!("{}", sweep_pareto_table(&rep).render());
        let stats = outcome.cache;
        println!(
            "sweep wall: {}   estimator memo: {} entries, {} hits / {} misses   {}",
            fmt_duration(outcome.wall_seconds),
            stats.entries,
            stats.hits,
            stats.misses,
            scheduler_line(&outcome)
        );
    }
    close_session(&session, json)
}

fn cmd_synth(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dev = pipeline::load_device(args.get("device").unwrap_or("arria10"))?;
    let quantize = args.has("quantize");
    let g = pipeline::load_model(model, quantize)?;
    let wants_quant = quantize && g.has_weights();
    // --report and --specialize upgrade the flow to full-network stepped
    // fidelity: the census is what both the report and the
    // specialization pass consume
    let fidelity = if args.has("report") || args.has("specialize") {
        Fidelity::SteppedFullNetwork
    } else {
        Fidelity::Analytical
    };
    let session = open_session_at(args, Some(fidelity))?;
    let mut builder = CompileJob::builder()
        .model(g)
        .device(dev)
        .explorer(CompileJob::explorer_from_args(args)?);
    if wants_quant {
        builder = builder.quantize(QuantSpec::default());
    }
    if args.has("specialize") {
        builder = builder.specialize();
    }
    builder = throughput_flags(builder, args)?;
    let outcome = session.run(&builder.build()?)?;
    let json = args.has("json");
    if json {
        print!("{}", outcome.to_json().to_string_pretty());
        return close_session(&session, json);
    }
    let rep = outcome
        .synth_report()
        .ok_or_else(|| anyhow!("synth outcome rendered no 1x1 report"))?;
    println!("model: {}   device: {}", rep.model, rep.device);
    match (&rep.estimate, &rep.sim) {
        (Some(est), Some(sim)) => {
            println!(
                "H_best = ({},{})   fmax = {:.0} MHz   synthesis ≈ {}",
                est.ni,
                est.nl,
                est.fmax_mhz,
                fmt_duration(rep.synthesis_minutes.unwrap_or(0.0) * 60.0)
            );
            println!(
                "resources: ALM {:.0} ({:.0}%)  DSP {:.0} ({:.0}%)  RAM {:.0} ({:.0}%)  regs ({:.0}%)",
                est.alms, est.p_lut, est.dsps, est.p_dsp, est.ram_blocks, est.p_mem, est.p_reg
            );
            println!("{}", fig6(sim).render());
            let gops = metrics::gops_per_s(sim.gops, sim.total_millis);
            println!(
                "latency {:.2} ms   throughput {gops:.1} GOp/s   density {:.3} GOp/s/DSP   efficiency {:.0}% of lane peak",
                sim.total_millis,
                metrics::gops_per_dsp(gops, est.dsps),
                100.0 * sim.efficiency()
            );
            if let Some(net) = &rep.stepped_network {
                println!("{}", stepped_census_table(sim, net).render());
            }
            if let Some(spec) = &rep.specialization {
                println!("{}", specialization_table(rep, spec).render());
                // Fig. 6 again, at the specialized design: the
                // analytical breakdown with each round at its own option
                let flow = ComputationFlow::extract(&pipeline::load_model(model, quantize)?)
                    .map_err(|e| anyhow!("{e}"))?;
                let sdim = spec.analytical_breakdown(&flow, dev);
                println!("{}", fig6_specialized(&sdim, spec).render());
            }
        }
        _ => println!("Does not fit on {}", rep.device),
    }
    if let Some(line) = throughput_line(rep) {
        println!("{line}");
    }
    if (args.has("report") || args.has("specialize")) && !rep.fits() {
        println!("(no stepped census: the design does not fit)");
    }
    if let Some(q) = &rep.quant {
        println!(
            "quantization: {} tensors, worst |err| {:.4}, worst saturation {:.2}%",
            q.tensors.len(),
            q.worst_abs_err(),
            100.0 * q.worst_sat_ratio()
        );
    }
    close_session(&session, json)
}

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    args.get_or("artifacts", "artifacts").into()
}

fn cmd_emulate(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let dir = artifacts_dir(args);
    match pipeline::run_emulation(&dir, model)? {
        Some(res) => {
            println!(
                "emulation {} OK: PJRT exec {}   golden max |err| = {:.3e}",
                res.model,
                fmt_duration(res.exec_seconds),
                res.golden_max_err.unwrap_or(f64::NAN)
            );
            Ok(())
        }
        None => {
            // no golden: time with synthetic weights instead (Table 1's
            // emulation column for the big models)
            let manifest = Manifest::load(&dir)?;
            let art = manifest
                .model(model)
                .ok_or_else(|| anyhow!("model '{model}' not in {}", dir.display()))?;
            let seconds = pipeline::time_emulation_synthetic(art, 1)?;
            println!(
                "emulation {model}: {} per frame (synthetic weights)",
                fmt_duration(seconds)
            );
            Ok(())
        }
    }
}

/// Size the serving micro-batch from the throughput DSE: co-optimize
/// (N_i, N_l, B) for the served model on the session's `--device`
/// (analytical fidelity, brute force — a handful of closed-form
/// evaluations) and take the chosen B. Runs on the session evaluator,
/// so a `--cache-dir` store both serves warm entries and absorbs the
/// sizing sweep. Falls back to 1 when the model fits nowhere.
fn throughput_batch_for(
    session: &Session,
    dev: &'static cnn2gate::estimator::Device,
    model: &str,
    latency_slo_ms: Option<f64>,
) -> Result<usize> {
    use cnn2gate::dse::{throughput, EvalRequest};
    let g = pipeline::load_model(model, false)?;
    let flow = ComputationFlow::extract(&g).map_err(|e| anyhow!("{e}"))?;
    let ev = session.evaluator();
    let th = session.thresholds();
    let choice = throughput::co_optimize(
        ev,
        &flow,
        dev,
        EvalRequest::at(Fidelity::Analytical),
        &[1, 2, 4, 8, 16],
        latency_slo_ms,
        |req| brute::explore_with_fidelity(ev, &flow, dev, th, req),
    );
    Ok(choice.chosen_batch())
}

/// Start the compile service with its inference lane bound to
/// `model`'s artifact, returning the input shape the demo feeds it.
/// The compile lane's evaluator shares `cache` (the serve session's
/// possibly store-backed memo).
fn start_infer_service(
    dir: &std::path::Path,
    model: &str,
    cfg: ServiceConfig,
    cache: Arc<EvalCache>,
) -> Result<(CompileService, Vec<usize>)> {
    let manifest = Manifest::load(dir)?;
    let art = manifest
        .model(model)
        .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?;
    let weights = match &art.golden {
        Some(g) => load_golden(g)?.params,
        None => pipeline::synthetic_weights(art, 7),
    };
    let service = CompileService::start_with_inference_cached(cfg, art, weights, cache)?;
    Ok((service, art.input.shape.clone()))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let compile_models = args.get_list("compile-models", &[]);
    let model = args.get("model").unwrap_or("lenet5");
    let dev = pipeline::load_device(args.get("device").unwrap_or("arria10"))?;
    // The session carries the cache policy: a --cache-dir store (or
    // legacy --cache-file) seeds both the batch-sizing DSE below and
    // the daemon's compile lane, and close_session persists what the
    // whole serve run computed.
    let session = open_session(args)?;
    // --batch pins the inference micro-batch cap; otherwise the
    // throughput DSE sizes it from the served model's (Ni, Nl, B)
    // co-optimization under the optional --latency-slo
    let max_batch = match args.get("batch") {
        Some(_) => args.get_usize("batch", 8)?,
        None => {
            let slo = CompileJob::latency_slo_from_args(args)?;
            let chosen = throughput_batch_for(&session, dev, model, slo)?;
            println!(
                "serve: micro-batch sized to {chosen} by the throughput DSE on {}{}",
                dev.name,
                match slo {
                    Some(ms) => format!(" under a {ms:.1} ms end-to-end SLO"),
                    None => String::new(),
                }
            );
            chosen
        }
    };
    let cfg = ServiceConfig {
        workers: args.get_usize("workers", 2)?,
        queue_capacity: args.get_usize("queue", 64)?,
        max_batch,
        ..ServiceConfig::default()
    };
    let dir = artifacts_dir(args);

    // One daemon serves both lanes, compile jobs running on the
    // session's cache handle. Without --compile-models the inference
    // lane is the whole demo, so its startup errors stay fatal (the
    // seed's behavior); with compile work queued the lane is
    // best-effort and the daemon comes up without it.
    let cache = session.evaluator().cache_handle();
    let (service, input_shape) = match start_infer_service(&dir, model, cfg, Arc::clone(&cache)) {
        Ok((service, shape)) => (service, Some(shape)),
        Err(e) if compile_models.is_empty() => return Err(e),
        Err(e) => {
            eprintln!("note: inference lane disabled — {e:#}");
            (CompileService::start_with_cache(cfg, cache), None)
        }
    };

    // Compile lane: submit every --compile-models entry through the
    // shared daemon, then stream each job's typed lifecycle events
    // (progress throttled to every tenth of the grid).
    let mut tickets = Vec::with_capacity(compile_models.len());
    for name in &compile_models {
        let job = CompileJob::builder()
            .model(pipeline::load_model(name, false)?)
            .all_devices()
            .explorer(Explorer::BruteForce)
            .build()?;
        let ticket = service.submit(JobSpec::new(job))?;
        println!("{}: accepted (compile {name}, fleet)", ticket.id());
        tickets.push(ticket);
    }
    for ticket in &tickets {
        let mut last_decile = 0;
        loop {
            let event = ticket.recv()?;
            match &event {
                Event::Progress { scored, total, .. } => {
                    let decile = 10 * scored / (*total).max(1);
                    if decile > last_decile {
                        last_decile = decile;
                        println!("{}", event.describe());
                    }
                }
                _ => println!("{}", event.describe()),
            }
            if event.is_terminal() {
                break;
            }
        }
    }

    // Inference lane: push synthetic frames through the same daemon.
    if let Some(shape) = input_shape {
        let n = args.get_usize("requests", 32)?;
        let numel: usize = shape.iter().product();
        let mut rng = Rng::new(11);
        for _ in 0..n {
            let input = match service.out_dtype() {
                Some(cnn2gate::ir::DType::F32) => Tensor::F32(shape.clone(), rng.tensor_f32(numel)),
                _ => Tensor::I32(
                    shape.clone(),
                    (0..numel).map(|_| rng.range_i64(-128, 127) as i32).collect(),
                ),
            };
            service.infer(input)?;
        }
    }

    let report = service.shutdown();
    if !tickets.is_empty() {
        let finished = report
            .reducer
            .jobs()
            .filter(|(_, r)| r.state == JobState::Finished)
            .count();
        println!(
            "compile lane: {} jobs, {} finished, {} events logged",
            report.reducer.jobs().count(),
            finished,
            report.reducer.log().len()
        );
    }
    if let Some(stats) = report.infer {
        println!(
            "served {} requests in {} batches: exec p50 {:.2} ms p99 {:.2} ms | e2e p50 {:.2} ms p99 {:.2} ms",
            stats.served,
            stats.batches,
            stats.exec.p50_ms,
            stats.exec.p99_ms,
            stats.e2e.p50_ms,
            stats.e2e.p99_ms
        );
    }
    // persist everything the sizing sweep AND the daemon's compile
    // jobs added to the shared memo
    close_session(&session, false)
}

fn cmd_tables(args: &Args) -> Result<()> {
    use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    let alex = zoo::build("alexnet", false).ok_or_else(|| anyhow!("zoo model 'alexnet' missing"))?;
    let vgg = zoo::build("vgg16", false).ok_or_else(|| anyhow!("zoo model 'vgg16' missing"))?;
    let aflow = ComputationFlow::extract(&alex).map_err(|e| anyhow!("{e}"))?;
    let vflow = ComputationFlow::extract(&vgg).map_err(|e| anyhow!("{e}"))?;

    // Table 1 (the CPU row needs a real PJRT backend — skipped on stub builds)
    let mut rows = Vec::new();
    let dir = artifacts_dir(args);
    if cnn2gate::runtime::Runtime::available() {
        if let Ok(manifest) = Manifest::load(&dir) {
            let a = manifest
                .model("alexnet")
                .map(|art| pipeline::time_emulation_synthetic(art, 1))
                .transpose()?;
            let v = manifest
                .model("vgg16")
                .map(|art| pipeline::time_emulation_synthetic(art, 1))
                .transpose()?;
            rows.push((
                "CPU (PJRT emulation)".to_string(),
                "N/A".to_string(),
                a.map(|s| s * 1e3),
                v.map(|s| s * 1e3),
                None,
            ));
        }
    }
    for (dev, ni, nl) in [(&CYCLONE_V_5CSEMA5, 8, 8), (&ARRIA_10_GX1150, 16, 32)] {
        let est = estimate(&aflow, dev, ni, nl);
        let asim = simulate(&aflow, dev, ni, nl);
        let vsim = simulate(&vflow, dev, ni, nl);
        rows.push((
            dev.name.to_string(),
            format!(
                "Logic {:.0}% DSP {:.0}% RAM {:.0}%",
                est.p_lut, est.p_dsp, est.p_mem
            ),
            Some(asim.total_millis),
            Some(vsim.total_millis),
            Some(est.fmax_mhz),
        ));
    }
    println!("{}", table1(&rows).render());

    // Table 2: one 1×3 CompileJob gives the synth column for all three
    // boards; the explorer timing columns come from the DSE layer
    let session = Session::builder().build();
    let boards = [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150];
    let outcome = session.run(
        &CompileJob::builder()
            .model(alex.clone())
            .devices(boards)
            .explorer(Explorer::BruteForce)
            .build()?,
    )?;
    let th = session.thresholds();
    let mut reports = Vec::new();
    for (rep, dev) in outcome.entries.into_iter().zip(boards) {
        let rl_res = rl::explore(&aflow, dev, th, RlConfig::default());
        let bf_res = brute::explore(&aflow, dev, th);
        reports.push((rep, rl_res, bf_res));
    }
    let refs: Vec<_> = reports.iter().map(|(a, b, c)| (a, b, c)).collect();
    println!("{}", table2(&refs).render());

    // Tables 3 + 4
    let est = estimate(&aflow, &ARRIA_10_GX1150, 16, 32);
    let asim = simulate(&aflow, &ARRIA_10_GX1150, 16, 32);
    println!(
        "{}",
        comparison_table(
            "Table 3: Comparison to existing works, AlexNet (Ni,Nl)=(16,32)",
            &baselines::alexnet(),
            &asim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );
    let vsim = simulate(&vflow, &ARRIA_10_GX1150, 16, 32);
    println!(
        "{}",
        comparison_table(
            "Table 4: Comparison to existing works, VGG-16 (Ni,Nl)=(16,32)",
            &baselines::vgg16(),
            &vsim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );

    // Fig 6
    println!("{}", fig6(&asim).render());
    Ok(())
}

fn cmd_devices(_args: &Args) -> Result<()> {
    for d in device::all() {
        println!(
            "{:<24} family {:?}  ALM {}  DSP {}  RAM blocks {}  mem {} bits  base {} MHz",
            d.name, d.family, d.alms, d.dsps, d.ram_blocks, d.mem_bits, d.base_clock_mhz
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry IS the help text: every flag and switch a
    /// subcommand accepts appears in the generated USAGE, so adding a
    /// flag (e.g. `--json`) cannot drift from the documentation.
    #[test]
    fn usage_lists_every_registered_flag_and_switch() {
        let usage = usage();
        for cmd in SUBCOMMANDS {
            assert!(usage.contains(cmd.name), "usage missing subcommand {}", cmd.name);
            for f in cmd.flags {
                assert!(usage.contains(&format!("--{}", f.name)), "usage missing --{}", f.name);
            }
            for s in cmd.switches {
                assert!(usage.contains(&format!("--{s}")), "usage missing --{s}");
            }
        }
        // the tentpole flag rides the registry like any other
        for name in ["synth", "fit-fleet", "sweep"] {
            let cmd = SUBCOMMANDS.iter().find(|c| c.name == name).unwrap();
            assert!(cmd.switches.contains(&"json"), "{name} must accept --json");
        }
    }

    #[test]
    fn dispatch_rejects_unknown_subcommands_and_flags() {
        let err = dispatch(&["frobnicate".to_string()]).unwrap_err();
        assert!(err.to_string().contains("unknown subcommand"), "{err}");
        // a flag valid on one subcommand is rejected on another
        let err = dispatch(&["devices".to_string(), "--model".into(), "x".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown flag --model"), "{err}");
    }

    #[test]
    fn registry_allowlists_parse_their_own_usage_flags() {
        // every registered value flag parses in both spellings
        for cmd in SUBCOMMANDS {
            let flags: Vec<&str> = cmd.flags.iter().map(|f| f.name).collect();
            for f in cmd.flags {
                let spaced = vec![
                    cmd.name.to_string(),
                    format!("--{}", f.name),
                    "1".to_string(),
                ];
                let inline = vec![cmd.name.to_string(), format!("--{}=1", f.name)];
                for argv in [spaced, inline] {
                    let parsed = Args::parse(&argv, &flags, cmd.switches).unwrap();
                    assert_eq!(parsed.get(f.name), Some("1"), "{} --{}", cmd.name, f.name);
                }
            }
        }
    }
}
