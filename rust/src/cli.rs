//! Hand-rolled CLI argument parsing (no clap in the offline crate set).
//!
//! Grammar: `cnn2gate <subcommand> [--flag value | --flag=value]...
//! [--switch]...` — both value-flag spellings are accepted. Unknown
//! flags are rejected against a per-subcommand allowlist so typos fail
//! loudly instead of silently using defaults.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse argv (without the program name). `allowed` lists the legal
    /// `--flag` names taking a value; `allowed_switches` the boolean ones.
    pub fn parse(
        argv: &[String],
        allowed: &[&str],
        allowed_switches: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next_if(|a| !a.starts_with("--")) {
            out.subcommand = first.clone();
        }
        while let Some(arg) = it.next() {
            let Some(token) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            // `--flag=value` is the inline spelling of `--flag value`;
            // only the first '=' splits, so values may contain '='
            let (name, inline) = match token.split_once('=') {
                Some((name, value)) => (name, Some(value)),
                None => (token, None),
            };
            if allowed_switches.contains(&name) {
                if inline.is_some() {
                    bail!("switch --{name} takes no value (got --{token})");
                }
                out.switches.push(name.to_string());
            } else if allowed.contains(&name) {
                let value = match inline {
                    Some(value) => value.to_string(),
                    None => it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?
                        .clone(),
                };
                out.flags.insert(name.to_string(), value);
            } else {
                bail!(
                    "unknown flag --{name} (value flags: {allowed:?}, switches: {allowed_switches:?})"
                );
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Like [`Args::get`] but mandatory, with a uniform error message —
    /// the `--model <m>`-style flags every subcommand insists on.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("--{name} required"))
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Value flag constrained to a closed set, with a uniform error
    /// listing the legal values (`--explorer rl|bf`,
    /// `--fidelity analytical|stepped|stepped-full`-style flags).
    pub fn get_choice<'a>(
        &'a self,
        name: &str,
        allowed: &[&'a str],
        default: &'a str,
    ) -> Result<&'a str> {
        let v = self.get_or(name, default);
        if allowed.contains(&v) {
            Ok(v)
        } else {
            bail!("--{name} must be one of {allowed:?}, got '{v}'")
        }
    }

    /// Comma-separated list flag (`--models alexnet,vgg16`); `default`
    /// when absent. Entries are trimmed and empty segments dropped, so
    /// `a,,b` and `a, b` both parse to two entries.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["synth", "--model", "alexnet", "--quantize"]),
            &["model"],
            &["quantize"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "synth");
        assert_eq!(a.get("model"), Some("alexnet"));
        assert!(a.has("quantize"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn rejects_unknown_flag() {
        let err = Args::parse(&sv(&["x", "--bogus", "1"]), &["model"], &[]).unwrap_err();
        assert!(err.to_string().contains("unknown flag"));
    }

    #[test]
    fn accepts_equals_spelling_for_value_flags() {
        let a = Args::parse(
            &sv(&["synth", "--model=alexnet", "--device", "arria10", "--quantize"]),
            &["model", "device"],
            &["quantize"],
        )
        .unwrap();
        assert_eq!(a.get("model"), Some("alexnet"));
        assert_eq!(a.get("device"), Some("arria10"));
        assert!(a.has("quantize"));
        // values may themselves contain '=' (only the first splits)
        let b = Args::parse(&sv(&["x", "--models=a=b,c"]), &["models"], &[]).unwrap();
        assert_eq!(b.get("models"), Some("a=b,c"));
        // an empty inline value is an explicit empty string
        let c = Args::parse(&sv(&["x", "--model="]), &["model"], &[]).unwrap();
        assert_eq!(c.get("model"), Some(""));
        // both spellings agree
        let d = Args::parse(
            &sv(&["sweep", "--fidelity=stepped-full"]),
            &["fidelity"],
            &[],
        )
        .unwrap();
        assert_eq!(
            d.get_choice("fidelity", &["analytical", "stepped", "stepped-full"], "analytical")
                .unwrap(),
            "stepped-full"
        );
    }

    #[test]
    fn rejects_equals_on_switches_and_unknown_equals_flags() {
        let err = Args::parse(&sv(&["x", "--quantize=yes"]), &[], &["quantize"]).unwrap_err();
        assert!(err.to_string().contains("takes no value"), "{err}");
        let err = Args::parse(&sv(&["x", "--bogus=1"]), &["model"], &[]).unwrap_err();
        assert!(err.to_string().contains("unknown flag --bogus"), "{err}");
    }

    #[test]
    fn rejects_missing_value() {
        let err = Args::parse(&sv(&["x", "--model"]), &["model"], &[]).unwrap_err();
        assert!(err.to_string().contains("needs a value"));
    }

    #[test]
    fn require_demands_presence() {
        let a = Args::parse(&sv(&["x", "--model", "alexnet"]), &["model", "device"], &[]).unwrap();
        assert_eq!(a.require("model").unwrap(), "alexnet");
        let err = a.require("device").unwrap_err();
        assert!(err.to_string().contains("--device required"));
    }

    #[test]
    fn list_getter_splits_and_defaults() {
        let a = Args::parse(
            &sv(&["x", "--models", "alexnet, vgg16,,tiny"]),
            &["models"],
            &[],
        )
        .unwrap();
        assert_eq!(a.get_list("models", &["lenet5"]), vec!["alexnet", "vgg16", "tiny"]);
        let b = Args::parse(&sv(&["x"]), &["models"], &[]).unwrap();
        assert_eq!(b.get_list("models", &["alexnet", "vgg16"]), vec!["alexnet", "vgg16"]);
    }

    #[test]
    fn choice_getter_enforces_the_allowed_set() {
        let a = Args::parse(&sv(&["x", "--fidelity", "stepped-full"]), &["fidelity"], &[]).unwrap();
        assert_eq!(
            a.get_choice("fidelity", &["analytical", "stepped", "stepped-full"], "analytical")
                .unwrap(),
            "stepped-full"
        );
        let b = Args::parse(&sv(&["x"]), &["fidelity"], &[]).unwrap();
        assert_eq!(
            b.get_choice("fidelity", &["analytical", "stepped"], "analytical").unwrap(),
            "analytical"
        );
        let c = Args::parse(&sv(&["x", "--fidelity", "bogus"]), &["fidelity"], &[]).unwrap();
        let err = c
            .get_choice("fidelity", &["analytical", "stepped"], "analytical")
            .unwrap_err();
        assert!(err.to_string().contains("must be one of"), "{err}");
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&sv(&["x", "--n", "8", "--t", "2.5"]), &["n", "t"], &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 8);
        assert_eq!(a.get_f64("t", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let bad = Args::parse(&sv(&["x", "--n", "abc"]), &["n"], &[]).unwrap();
        assert!(bad.get_usize("n", 0).is_err());
    }
}
