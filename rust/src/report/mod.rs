//! Paper-table regeneration: literature baselines (Tables 3-4 columns)
//! and renderers for Tables 1-4 + Fig. 6.

pub mod baselines;
pub mod tables;

pub use baselines::BaselineRow;
pub use tables::{
    comparison_table, fig6, fig6_specialized, fleet_table, specialization_table,
    stepped_census_table, sweep_best_device_table, sweep_best_model_table, sweep_pareto_table,
    sweep_table, sweep_throughput_table, table1, table2,
};
