//! Literature baseline rows for Tables 3-4.
//!
//! These are *published numbers from the cited works*, encoded as data —
//! the comparison baselines the paper reports against. Our own rows are
//! computed live from the simulator + estimator; the baselines anchor
//! the who-wins / by-what-factor shape checks in the benches.

/// One comparison row (a column of the paper's Tables 3-4).
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineRow {
    /// Citation tag as printed in the paper.
    pub work: &'static str,
    pub fpga: &'static str,
    pub synthesis_method: &'static str,
    /// Kernel clock in MHz (None where the paper prints "-").
    pub freq_mhz: Option<f64>,
    /// Logic utilization, count and percent (None where unreported).
    pub logic: Option<(f64, f64)>,
    /// DSP utilization, count and percent.
    pub dsp: Option<(f64, f64)>,
    /// Latency in ms (batch 1) — None where unreported.
    pub latency_ms: Option<f64>,
    pub precision: &'static str,
    /// Performance in GOp/s.
    pub gops: f64,
}

/// Table 3 baselines: AlexNet.
pub fn alexnet() -> Vec<BaselineRow> {
    vec![
        BaselineRow {
            work: "AlexNet[21] (Zhang FPGA'15)",
            fpga: "Virtex-7 VX485T",
            synthesis_method: "C/C++",
            freq_mhz: Some(100.0),
            logic: Some((186_000.0, 61.0)),
            dsp: Some((2240.0, 80.0)),
            latency_ms: Some(21.61),
            precision: "32 float",
            gops: 61.62,
        },
        BaselineRow {
            work: "AlexNet[22] (Ma FPL'16)",
            fpga: "Stratix-V GXA7",
            synthesis_method: "RTL",
            freq_mhz: Some(100.0),
            logic: Some((121_000.0, 52.0)),
            dsp: Some((256.0, 100.0)),
            latency_ms: Some(12.75),
            precision: "8-16 fixed",
            gops: 114.5,
        },
        BaselineRow {
            work: "AlexNet[8] (fpgaConvNet)",
            fpga: "Zynq 7045",
            synthesis_method: "C/C++",
            freq_mhz: Some(125.0),
            logic: None,
            dsp: Some((897.0, 99.5)),
            latency_ms: Some(8.22),
            precision: "16 fixed",
            gops: 161.98,
        },
        BaselineRow {
            work: "AlexNet[20] (Suda FPGA'16)",
            fpga: "Stratix-V GX-D8",
            synthesis_method: "OpenCL",
            freq_mhz: None,
            logic: Some((120_000.0, 17.0)),
            dsp: Some((665.0, 34.0)),
            latency_ms: Some(20.1),
            precision: "8-16 fixed",
            gops: 72.4,
        },
    ]
}

/// Table 4 baselines: VGG-16.
pub fn vgg16() -> Vec<BaselineRow> {
    vec![
        BaselineRow {
            work: "VGG-16[39] (Qiu FPGA'16)",
            fpga: "Zynq 7045",
            synthesis_method: "-",
            freq_mhz: Some(150.0),
            logic: Some((182_000.0, 83.5)),
            dsp: Some((780.0, 89.2)),
            latency_ms: None,
            precision: "16 fixed",
            gops: 136.91,
        },
        BaselineRow {
            work: "VGG-16[10] (Ma FPGA'17)",
            fpga: "Arria 10 GX1150",
            synthesis_method: "RTL",
            freq_mhz: Some(150.0),
            logic: Some((161_000.0, 38.0)),
            dsp: Some((1518.0, 100.0)),
            latency_ms: Some(47.97),
            precision: "8-16 fixed",
            gops: 645.25,
        },
        BaselineRow {
            work: "VGG-16[8] (fpgaConvNet)",
            fpga: "Zynq 7045",
            synthesis_method: "C/C++",
            freq_mhz: Some(125.0),
            logic: None,
            dsp: Some((855.0, 95.0)),
            latency_ms: Some(249.5),
            precision: "16 fixed",
            gops: 161.98,
        },
        BaselineRow {
            work: "VGG-16[20] (Suda FPGA'16)",
            fpga: "Stratix-V GX-D8",
            synthesis_method: "OpenCL",
            freq_mhz: Some(120.0),
            logic: None,
            dsp: None,
            latency_ms: Some(262.9),
            precision: "8-16 fixed",
            gops: 117.8,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_has_four_baselines() {
        let rows = alexnet();
        assert_eq!(rows.len(), 4);
        // the paper's qualitative claims about the baselines
        let suda = &rows[3];
        assert_eq!(suda.synthesis_method, "OpenCL");
        assert!(suda.latency_ms.unwrap() > 18.24, "CNN2Gate beats [20]");
        let fpgaconvnet = &rows[2];
        assert!(fpgaconvnet.latency_ms.unwrap() < 18.24, "[8] beats CNN2Gate on AlexNet");
    }

    #[test]
    fn table4_shape_claims() {
        let rows = vgg16();
        assert_eq!(rows.len(), 4);
        // paper: "CNN2Gate achieves 18% lower latency than [8]" on VGG
        let fpgaconvnet = rows.iter().find(|r| r.work.contains("[8]")).unwrap();
        let ours = 205.0;
        let gain = 1.0 - ours / fpgaconvnet.latency_ms.unwrap();
        assert!((gain - 0.18).abs() < 0.02, "gain {gain}");
        // paper: hand-tailored RTL [10] is faster than CNN2Gate
        let ma = rows.iter().find(|r| r.work.contains("[10]")).unwrap();
        assert!(ma.latency_ms.unwrap() < ours);
    }

    #[test]
    fn performance_density_claim() {
        // §5: ours 0.266 GOp/s/DSP vs 0.234 for [20]
        let suda = &alexnet()[3];
        let density = suda.gops / suda.dsp.unwrap().0;
        assert!((density - 0.109).abs() < 0.01 || density < 0.266);
    }
}
