//! Renderers that regenerate the paper's tables/figure from live results.
//!
//! Each function takes the structs the pipeline computed (SimReport,
//! SynthReport, DseResult, baselines) and prints the same rows the paper
//! reports. The benches call these; `cnn2gate report` exposes them on the
//! CLI.

use crate::coordinator::pipeline::SweepReport;
use crate::dse::DseResult;
use crate::metrics;
use crate::sim::{NetworkStepReport, SimReport};
use crate::synth::{Explorer, SynthReport};
use crate::util::table::{fmt_count, fmt_duration, Table};

use super::baselines::BaselineRow;

/// Table 1: execution times for AlexNet and VGG (batch size = 1).
/// `rows` = (platform label, resource summary, alexnet_ms, vgg_ms, fmax).
pub fn table1(rows: &[(String, String, Option<f64>, Option<f64>, Option<f64>)]) -> Table {
    let mut t = Table::new(
        "Table 1: Execution times for AlexNet and VGG (batch size = 1)",
        &["Platform", "Resource Utilization", "AlexNet", "VGG-16", "f_max"],
    );
    for (platform, resources, alex, vgg, fmax) in rows {
        t.row(&[
            platform.clone(),
            resources.clone(),
            alex.map_or("N/A".into(), |ms| fmt_duration(ms / 1e3)),
            vgg.map_or("N/A".into(), |ms| fmt_duration(ms / 1e3)),
            fmax.map_or("N/A".into(), |f| format!("{f:.0} MHz")),
        ]);
    }
    t.footnote("resource utilization shown for AlexNet");
    t
}

/// Table 2: synthesis and DSE details (AlexNet).
pub fn table2(reports: &[(&SynthReport, &DseResult, &DseResult)]) -> Table {
    // reports: (synth report, rl result, bf result) per platform
    let mut t = Table::new(
        "Table 2: CNN2Gate Synthesis and Design-Space Exploration Details (AlexNet)",
        &[
            "Platform",
            "RL-DSE time",
            "BF-DSE time",
            "Synthesis time",
            "Resources Consumed",
            "Hardware Options (Ni,Nl)",
        ],
    );
    for (rep, rl, bf) in reports {
        let consumed = match &rep.estimate {
            Some(e) => format!(
                "ALM: {} DSP: {:.0} RAM: {:.0} Mem: {} bits",
                fmt_count(e.alms),
                e.dsps,
                e.ram_blocks,
                fmt_count(e.mem_bits)
            ),
            None => "Does not fit".into(),
        };
        t.row(&[
            rep.device.to_string(),
            fmt_duration(rl.modeled_seconds),
            fmt_duration(bf.modeled_seconds),
            rep.synthesis_minutes
                .map_or("N/A".into(), |m| fmt_duration(m * 60.0)),
            consumed,
            rep.option()
                .map_or("N/A".into(), |(ni, nl)| format!("({ni},{nl})")),
        ]);
    }
    t
}

/// Fleet-fit comparison: one model fitted across the device database
/// (the `fit-fleet` subcommand's output). `entries` come in job order
/// from a 1×N session run's
/// [`FleetReport`](crate::coordinator::pipeline::FleetReport); devices
/// that don't fit render a "Does not fit" row.
pub fn fleet_table(model: &str, entries: &[SynthReport]) -> Table {
    let mut t = Table::new(
        format!("Fleet fit: {model} across the FPGA device database"),
        &[
            "Device",
            "Option (Ni,Nl)",
            "F_avg",
            "ALM",
            "DSP",
            "RAM",
            "f_max",
            "Latency",
            "GOp/s",
            "Synthesis",
            "Queries (cached)",
        ],
    );
    for rep in entries {
        match (&rep.estimate, &rep.sim) {
            (Some(est), Some(sim)) => {
                let gops = metrics::gops_per_s(sim.gops, sim.total_millis);
                t.row(&[
                    rep.device.to_string(),
                    format!("({},{})", est.ni, est.nl),
                    format!("{:.1}%", est.f_avg()),
                    format!("{:.0}%", est.p_lut),
                    format!("{:.0}%", est.p_dsp),
                    format!("{:.0}%", est.p_mem),
                    format!("{:.0} MHz", est.fmax_mhz),
                    format!("{:.2} ms", sim.total_millis),
                    format!("{gops:.1}"),
                    rep.synthesis_minutes
                        .map_or("N/A".into(), |m| fmt_duration(m * 60.0)),
                    format!("{} ({})", rep.dse.queries, rep.dse.cache_hits),
                ]);
            }
            _ => {
                t.row(&[
                    rep.device.to_string(),
                    "Does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("{} ({})", rep.dse.queries, rep.dse.cache_hits),
                ]);
            }
        }
    }
    t.footnote(format!("devices in database order; {}", batch_note(entries)));
    t
}

fn explorer_tag(explorer: Explorer) -> &'static str {
    match explorer {
        Explorer::BruteForce => "bf",
        Explorer::Reinforcement => "rl",
    }
}

/// The latency footnote's batch clause, derived from the entries the
/// table renders (the old hardcoded "batch 1" misreported
/// throughput-mode runs, whose latencies are simulated at each entry's
/// chosen batch).
fn batch_note(entries: &[SynthReport]) -> String {
    let mut batches: Vec<usize> = entries.iter().map(|e| e.batch.max(1)).collect();
    batches.sort_unstable();
    batches.dedup();
    match batches.as_slice() {
        [] => "latency simulated at batch 1".to_string(),
        [b] => format!("latency simulated at batch {b}"),
        many => format!(
            "latency simulated at batches {}",
            many.iter().map(|b| b.to_string()).collect::<Vec<_>>().join("/")
        ),
    }
}

/// (option, F_avg, f_max, latency, GOp/s) cells for a fitting report;
/// `None` when the design does not fit.
fn fit_cells(rep: &SynthReport) -> Option<[String; 5]> {
    match (&rep.estimate, &rep.sim) {
        (Some(est), Some(sim)) => {
            let gops = metrics::gops_per_s(sim.gops, sim.total_millis);
            Some([
                format!("({},{})", est.ni, est.nl),
                format!("{:.1}%", est.f_avg()),
                format!("{:.0} MHz", est.fmax_mhz),
                format!("{:.2} ms", sim.total_millis),
                format!("{gops:.1}"),
            ])
        }
        _ => None,
    }
}

/// Model×device sweep matrix — the `sweep` subcommand's main table.
/// Deliberately excludes cache-hit counters, so a warm (`--cache-file`)
/// re-run renders byte-identically to the cold run; memo statistics are
/// printed separately.
pub fn sweep_table(rep: &SweepReport) -> Table {
    let mut t = Table::new(
        format!(
            "Sweep: {} model(s) x {} device(s), {}-dse",
            rep.models.len(),
            rep.devices().len(),
            explorer_tag(rep.explorer)
        ),
        &[
            "Model",
            "Device",
            "Option (Ni,Nl)",
            "F_avg",
            "f_max",
            "Latency",
            "GOp/s",
            "Synthesis",
        ],
    );
    for e in &rep.entries {
        match fit_cells(e) {
            Some([option, favg, fmax, latency, gops]) => {
                t.row(&[
                    e.model.clone(),
                    e.device.to_string(),
                    option,
                    favg,
                    fmax,
                    latency,
                    gops,
                    e.synthesis_minutes
                        .map_or("N/A".into(), |m| fmt_duration(m * 60.0)),
                ]);
            }
            None => {
                t.row(&[
                    e.model.clone(),
                    e.device.to_string(),
                    "Does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t.footnote(format!(
        "model-major, devices in job order; {}",
        batch_note(&rep.entries)
    ));
    t
}

/// Frames/s ranking from a throughput-mode sweep (`sweep --batch`):
/// one row per entry that ran the (Ni, Nl, B) co-optimization, ranked
/// by frames/s descending (ties keep job order, so the rendering is
/// deterministic). Entries without a throughput sweep — classic
/// batch-1 jobs mixed into the matrix — are skipped.
pub fn sweep_throughput_table(rep: &SweepReport) -> Table {
    let mut t = Table::new(
        "Throughput ranking: frames/s at the chosen batch",
        &[
            "Model",
            "Device",
            "Batch",
            "Option (Ni,Nl)",
            "Frames/s",
            "Batch makespan",
            "E2E latency",
            "SLO",
        ],
    );
    let mut ranked: Vec<&SynthReport> =
        rep.entries.iter().filter(|e| e.throughput.is_some()).collect();
    ranked.sort_by(|a, b| {
        let fps = |e: &SynthReport| {
            e.throughput
                .as_ref()
                .and_then(|c| c.chosen_candidate())
                .map_or(0.0, |c| c.frames_per_s)
        };
        fps(b).total_cmp(&fps(a))
    });
    for e in ranked {
        let choice = e.throughput.as_ref().expect("filtered to Some above");
        match choice.chosen_candidate() {
            Some(c) => {
                let slo = match choice.latency_slo_ms {
                    Some(ms) if c.meets_slo => format!("meets {ms:.1} ms"),
                    Some(ms) => format!("misses {ms:.1} ms"),
                    None => "-".into(),
                };
                t.row(&[
                    e.model.clone(),
                    e.device.to_string(),
                    c.batch.to_string(),
                    c.option()
                        .map_or("-".into(), |(ni, nl)| format!("({ni},{nl})")),
                    format!("{:.1}", c.frames_per_s),
                    format!("{:.2} ms", c.batch_millis),
                    format!("{:.2} ms", c.e2e_millis),
                    slo,
                ]);
            }
            None => {
                t.row(&[
                    e.model.clone(),
                    e.device.to_string(),
                    "-".into(),
                    "Does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t.footnote("frames/s descending; each row's batch is its own co-optimization winner");
    t
}

/// Ranking: the lowest-latency fitting device for every model.
pub fn sweep_best_device_table(rep: &SweepReport) -> Table {
    let mut t = Table::new(
        "Best device per model",
        &["Model", "Device", "Option", "Latency", "F_avg"],
    );
    for (model, best) in rep.best_device_per_model() {
        match best.and_then(|b| fit_cells(b).map(|c| (b, c))) {
            Some((b, [option, favg, _, latency, _])) => {
                t.row(&[
                    model.to_string(),
                    b.device.to_string(),
                    option,
                    latency,
                    favg,
                ]);
            }
            None => {
                t.row(&[
                    model.to_string(),
                    "none fits".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t
}

/// Ranking: the lowest-latency fitting model for every device.
pub fn sweep_best_model_table(rep: &SweepReport) -> Table {
    let mut t = Table::new(
        "Best model per device",
        &["Device", "Model", "Option", "Latency", "F_avg"],
    );
    for (device, best) in rep.best_model_per_device() {
        match best.and_then(|b| fit_cells(b).map(|c| (b, c))) {
            Some((b, [option, favg, _, latency, _])) => {
                t.row(&[
                    device.to_string(),
                    b.model.clone(),
                    option,
                    latency,
                    favg,
                ]);
            }
            None => {
                t.row(&[
                    device.to_string(),
                    "none fits".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t
}

/// The matrix-wide latency/resource Pareto frontier.
pub fn sweep_pareto_table(rep: &SweepReport) -> Table {
    let mut t = Table::new(
        "Pareto frontier: latency vs resource usage",
        &["Model", "Device", "Option", "Latency", "F_avg"],
    );
    for e in rep.pareto_frontier() {
        if let Some([option, favg, _, latency, _]) = fit_cells(e) {
            t.row(&[e.model.clone(), e.device.to_string(), option, latency, favg]);
        }
    }
    t.footnote("fitting (model, device) points no other fit beats on both latency and F_avg");
    t
}

/// Per-layer stall/backpressure census from a full-network stepped run
/// (the `synth --report` path at `SteppedFullNetwork` fidelity). Rows
/// align with the latency breakdown's fused rounds; the verdict column
/// names what actually limited each round in the cycle-accurate model.
pub fn stepped_census_table(sim: &SimReport, net: &NetworkStepReport) -> Table {
    let mut t = Table::new(
        format!(
            "Stepped census: {} on {} (Ni,Nl)=({},{}) @ {:.0} MHz",
            sim.model, sim.device, sim.ni, sim.nl, net.fmax_mhz
        ),
        &[
            "Round",
            "Cycles",
            "Conv util",
            "DDR-starved",
            "Backpressure",
            "Verdict",
        ],
    );
    let bottleneck = net.bottleneck();
    for (i, (census, layer)) in net.layers.iter().zip(&sim.layers).enumerate() {
        let cycles = census.cycles.max(1);
        let starved = census.conv_empty_stalls as f64 / cycles as f64;
        let backpressure =
            (census.rd_to_conv_full_stalls + census.conv_to_wr_full_stalls) as f64 / cycles as f64;
        // multi-producer (Add-merge) rounds carry per-feed starvation
        // counters; when one branch dominates, name it — that is the
        // branch whose upstream round the schedule should rebalance
        let verdict = if starved > 0.25 {
            if census.feed_b_empty_stalls > census.feed_a_empty_stalls {
                "memory-bound (skip branch starved)"
            } else if census.feed_a_empty_stalls > census.feed_b_empty_stalls {
                "memory-bound (main branch starved)"
            } else {
                "memory-bound (starved)"
            }
        } else if backpressure > 0.25 {
            "write-bound (backpressured)"
        } else {
            "compute-bound (streaming)"
        };
        let marker = if Some(i) == bottleneck { " <- bottleneck" } else { "" };
        t.row(&[
            layer.label.clone(),
            fmt_count(census.cycles as f64),
            format!("{:.0}%", 100.0 * census.conv_utilization()),
            format!("{:.0}%", 100.0 * starved),
            format!("{:.0}%", 100.0 * backpressure),
            format!("{verdict}{marker}"),
        ]);
    }
    t.footnote(format!(
        "total {} cycles ≈ {:.2} ms at the kernel clock; lane utilization {:.0}%",
        fmt_count(net.total_cycles() as f64),
        net.total_millis(),
        100.0 * net.conv_utilization()
    ));
    t
}

/// Per-layer specialization table (the `synth --specialize` path): one
/// row per fused round with its specialized option, weight schedule and
/// cycles before/after, plus the totals and the resource delta of the
/// envelope in the footnote.
pub fn specialization_table(
    rep: &SynthReport,
    spec: &crate::dse::SpecializationReport,
) -> Table {
    let mut t = Table::new(
        format!(
            "Per-layer specialization: {} on {} from uniform ({},{})",
            rep.model, rep.device, spec.uniform.0, spec.uniform.1
        ),
        &[
            "Round",
            "Option (Ni,Nl)",
            "Schedule",
            "Cycles (uniform)",
            "Cycles (specialized)",
            "Gain",
        ],
    );
    for l in &spec.layers {
        let gain = if l.uniform_cycles == 0 {
            0.0
        } else {
            100.0 * (1.0 - l.cycles as f64 / l.uniform_cycles as f64)
        };
        t.row(&[
            l.label.clone(),
            format!("({},{})", l.ni, l.nl),
            crate::sim::schedule_tag(l.schedule).to_string(),
            fmt_count(l.uniform_cycles as f64),
            fmt_count(l.cycles as f64),
            if l.specialized() {
                format!("{gain:.1}%")
            } else {
                "-".to_string()
            },
        ]);
    }
    let delta_alms = spec.envelope_estimate.alms
        - rep.estimate.as_ref().map_or(spec.envelope_estimate.alms, |e| e.alms);
    // batched runs add the serving payoff; batch-1 footnotes are
    // byte-identical to the chain-era rendering
    let serving = if spec.batch > 1 {
        format!(
            "; batch {} serves {:.1} frames/s specialized",
            spec.batch,
            spec.specialized_frames_per_s()
        )
    } else {
        String::new()
    };
    t.footnote(format!(
        "total {} -> {} cycles ({:.1}% fewer) at {:.0} MHz; envelope ({},{}), resource delta {:+.0} ALMs{}",
        fmt_count(spec.uniform_total_cycles() as f64),
        fmt_count(spec.specialized_total_cycles() as f64),
        100.0 * spec.gain_fraction(),
        spec.fmax_mhz,
        spec.envelope.0,
        spec.envelope.1,
        delta_alms,
        serving,
    ));
    t
}

/// Tables 3/4: comparison to existing works.
pub fn comparison_table(
    title: &str,
    baselines: &[BaselineRow],
    ours: &SimReport,
    our_logic: (f64, f64),
    our_dsp: (f64, f64),
) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Work", "FPGA", "Method", "Freq (MHz)", "Logic", "DSP", "Latency (ms)",
            "Precision", "Perf (GOp/s)", "GOp/s/DSP",
        ],
    );
    for b in baselines {
        t.row(&[
            b.work.to_string(),
            b.fpga.to_string(),
            b.synthesis_method.to_string(),
            b.freq_mhz.map_or("-".into(), |f| format!("{f:.0}")),
            b.logic
                .map_or("-".into(), |(n, p)| format!("{} ({p:.0}%)", fmt_count(n))),
            b.dsp
                .map_or("-".into(), |(n, p)| format!("{n:.0} ({p:.1}%)")),
            b.latency_ms.map_or("-".into(), |l| format!("{l:.2}")),
            b.precision.to_string(),
            format!("{:.2}", b.gops),
            b.dsp
                .map_or("-".into(), |(n, _)| format!("{:.3}", metrics::gops_per_dsp(b.gops, n))),
        ]);
    }
    let our_gops = metrics::gops_per_s(ours.gops, ours.total_millis);
    t.row(&[
        format!("{} [This work]", ours.model),
        ours.device.clone(),
        "OpenCL (sim)".into(),
        format!("{:.0}", ours.fmax_mhz),
        format!("{} ({:.0}%)", fmt_count(our_logic.0), our_logic.1),
        format!("{:.0} ({:.1}%)", our_dsp.0, our_dsp.1),
        format!("{:.2}", ours.total_millis),
        "8 fixed".into(),
        format!("{our_gops:.2}"),
        format!("{:.3}", metrics::gops_per_dsp(our_gops, our_dsp.0)),
    ]);
    t.footnote("batch size = 1; baselines are published numbers from the cited works");
    t
}

/// Fig. 6: per-layer execution-time breakdown with ASCII bars.
pub fn fig6(rep: &SimReport) -> Table {
    let mut t = Table::new(
        format!(
            "Fig. 6: per-layer execution time, {} on {} (Ni,Nl)=({},{})",
            rep.model, rep.device, rep.ni, rep.nl
        ),
        &["Round", "Time (ms)", "MACs (M)", "Bound", "Bar"],
    );
    let max_ms = rep
        .layers
        .iter()
        .map(|l| l.millis)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    for l in &rep.layers {
        let width = ((l.millis / max_ms) * 40.0).round() as usize;
        t.row(&[
            l.label.clone(),
            format!("{:.3}", l.millis),
            format!("{:.1}", l.macs as f64 / 1e6),
            if l.memory_bound { "memory" } else { "compute" }.into(),
            "#".repeat(width.max(1)),
        ]);
    }
    t.footnote(format!("total {:.2} ms", rep.total_millis));
    t
}

/// Fig. 6 at the specialized design (the `synth --specialize` path):
/// the per-layer breakdown of
/// [`analytical_breakdown`](crate::dse::SpecializationReport::analytical_breakdown)
/// with each round's own option and weight schedule alongside the bars,
/// so the figure renders the specialized network rather than the
/// uniform winner.
pub fn fig6_specialized(
    rep: &SimReport,
    spec: &crate::dse::SpecializationReport,
) -> Table {
    let mut t = Table::new(
        format!(
            "Fig. 6 (specialized): per-layer execution time, {} on {} from uniform ({},{})",
            rep.model, rep.device, spec.uniform.0, spec.uniform.1
        ),
        &["Round", "Option (Ni,Nl)", "Schedule", "Time (ms)", "Bound", "Bar"],
    );
    let max_ms = rep
        .layers
        .iter()
        .map(|l| l.millis)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    for (l, s) in rep.layers.iter().zip(&spec.layers) {
        let width = ((l.millis / max_ms) * 40.0).round() as usize;
        t.row(&[
            l.label.clone(),
            format!("({},{})", s.ni, s.nl),
            crate::sim::schedule_tag(s.schedule).to_string(),
            format!("{:.3}", l.millis),
            if l.memory_bound { "memory" } else { "compute" }.into(),
            "#".repeat(width.max(1)),
        ]);
    }
    t.footnote(format!(
        "total {:.2} ms at {:.0} MHz; envelope ({},{})",
        rep.total_millis, rep.fmax_mhz, rep.ni, rep.nl
    ));
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Fidelity;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::estimator::Device;
    use crate::ir::ComputationFlow;
    use crate::onnx::zoo;
    use crate::report::baselines;
    use crate::session::{CompileJob, Session};
    use crate::sim::simulate;

    fn alexnet_sim() -> SimReport {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        simulate(&flow, &ARRIA_10_GX1150, 16, 32)
    }

    fn solo(model: &str, device: &'static Device) -> SynthReport {
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build(model, false).unwrap())
            .device(device)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        session.run(&job).unwrap().into_synth_report().unwrap()
    }

    fn full_sweep(models: &[&str]) -> SweepReport {
        let session = Session::builder().threads(4).build();
        let job = CompileJob::builder()
            .models(models.iter().map(|m| zoo::build(m, false).unwrap()))
            .all_devices()
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        session.run(&job).unwrap().to_sweep_report()
    }

    #[test]
    fn table1_renders() {
        let t = table1(&[(
            "Arria 10".into(),
            "Logic: 30% DSP: 20%".into(),
            Some(18.0),
            Some(205.0),
            Some(199.0),
        )]);
        let s = t.render();
        assert!(s.contains("18.0 ms") && s.contains("205.0 ms"));
    }

    #[test]
    fn fleet_table_renders_fits_and_no_fits() {
        use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4};
        let entries = vec![
            solo("alexnet", &ARRIA_10_GX1150),
            solo("alexnet", &CYCLONE_V_5CSEMA4),
        ];
        let t = fleet_table("alexnet", &entries);
        assert_eq!(t.rows.len(), 2);
        let s = t.render();
        assert!(s.contains("(16,32)"), "{s}");
        assert!(s.contains("Does not fit"), "{s}");
        assert!(s.contains("Arria 10"));
    }

    #[test]
    fn sweep_tables_render_matrix_rankings_and_frontier() {
        let rep = full_sweep(&["alexnet", "vgg16"]);
        let matrix = sweep_table(&rep);
        assert_eq!(matrix.rows.len(), rep.entries.len());
        let s = matrix.render();
        assert!(s.contains("alexnet") && s.contains("vgg16"), "{s}");
        assert!(s.contains("(16,32)") && s.contains("Does not fit"), "{s}");
        // cache-hit counters must never appear: a warm re-run has to
        // render byte-identically to the cold run
        assert!(!s.contains("cached"), "{s}");
        let best_dev = sweep_best_device_table(&rep);
        assert_eq!(best_dev.rows.len(), rep.models.len());
        assert!(best_dev.render().contains("Arria 10"));
        let best_model = sweep_best_model_table(&rep);
        assert_eq!(
            best_model.rows.len(),
            crate::estimator::device::all().len()
        );
        assert!(best_model.render().contains("none fits"), "5CSEMA4 row");
        let pareto = sweep_pareto_table(&rep);
        assert_eq!(pareto.rows.len(), rep.pareto_frontier().len());
        assert!(!pareto.rows.is_empty());
    }

    #[test]
    fn subset_sweep_tables_cover_only_the_jobs_devices() {
        // ROADMAP follow-up (f) at the renderer level: a subset sweep's
        // tables must neither count nor rank devices outside the job
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        let rep = session.run(&job).unwrap().to_sweep_report();
        let matrix = sweep_table(&rep);
        assert!(
            matrix.render().contains("1 model(s) x 1 device(s)"),
            "title counts the job's devices, not the database's"
        );
        let best_model = sweep_best_model_table(&rep);
        assert_eq!(best_model.rows.len(), 1, "one row per job device");
        let s = best_model.render();
        assert!(s.contains("Arria 10"), "{s}");
        assert!(
            !s.contains("none fits"),
            "no spurious rows for devices the job never evaluated: {s}"
        );
    }

    #[test]
    fn batch_note_derives_from_the_entries() {
        assert_eq!(batch_note(&[]), "latency simulated at batch 1");
        let a = solo("alexnet", &ARRIA_10_GX1150);
        assert_eq!(a.batch, 1, "classic jobs report batch 1");
        assert_eq!(batch_note(&[a.clone()]), "latency simulated at batch 1");
        let mut b = a.clone();
        b.batch = 16;
        assert_eq!(batch_note(&[b.clone()]), "latency simulated at batch 16");
        assert_eq!(
            batch_note(&[a, b]),
            "latency simulated at batches 1/16",
            "mixed batches list every distinct B"
        );
    }

    #[test]
    fn sweep_throughput_table_ranks_the_co_optimization() {
        let session = Session::builder().threads(4).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .batches([1, 16])
            .latency_slo_ms(1000.0)
            .build()
            .unwrap();
        let rep = session.run(&job).unwrap().to_sweep_report();
        let t = sweep_throughput_table(&rep);
        assert_eq!(t.rows.len(), 1, "one throughput row per entry");
        let s = t.render();
        assert!(s.contains("(16,32)"), "{s}");
        assert!(s.contains("meets 1000.0 ms"), "{s}");
        // the matrix footnote now reports the chosen batch, not a
        // hardcoded "batch 1"
        let matrix = sweep_table(&rep).render();
        assert!(matrix.contains("latency simulated at batch 16"), "{matrix}");
        // classic sweeps have no throughput rows and keep the old note
        let classic = full_sweep(&["alexnet"]);
        assert!(classic.entries.iter().all(|e| e.throughput.is_none()));
        assert_eq!(sweep_throughput_table(&classic).rows.len(), 0);
        assert!(sweep_table(&classic)
            .render()
            .contains("latency simulated at batch 1"));
    }

    #[test]
    fn specialization_table_renders_rounds_and_totals() {
        let session = Session::builder()
            .threads(4)
            .fidelity(Fidelity::SteppedFullNetwork)
            .build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .specialize()
            .build()
            .unwrap();
        let rep = session.run(&job).unwrap().into_synth_report().unwrap();
        let spec = rep.specialization.clone().expect("specialization present");
        let t = specialization_table(&rep, &spec);
        assert_eq!(t.rows.len(), rep.sim.as_ref().unwrap().layers.len());
        let s = t.render();
        assert!(s.contains("slice-resident"), "{s}");
        assert!(s.contains("streamed"), "{s}");
        assert!(s.contains("L1 conv+pool"), "{s}");
        assert!(s.contains("fewer"), "{s}");
    }

    #[test]
    fn stepped_census_table_aligns_with_rounds() {
        use crate::estimator::estimate;
        use crate::sim::step_network;
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let sim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
        let net = step_network(&flow, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32);
        let t = stepped_census_table(&sim, &net);
        assert_eq!(t.rows.len(), 8, "one row per fused round");
        let s = t.render();
        assert!(s.contains("bottleneck"), "{s}");
        assert!(s.contains("L1 conv"), "{s}");
        // at (16,32) the conv rounds are DDR-starved in the cycle model
        assert!(
            s.contains("memory-bound") || s.contains("compute-bound"),
            "{s}"
        );
    }

    #[test]
    fn comparison_table_includes_all_rows() {
        let sim = alexnet_sim();
        let t = comparison_table(
            "Table 3",
            &baselines::alexnet(),
            &sim,
            (129_000.0, 30.0),
            (300.0, 20.0),
        );
        let s = t.render();
        assert_eq!(t.rows.len(), 5); // 4 baselines + ours
        assert!(s.contains("This work"));
        assert!(s.contains("fpgaConvNet"));
    }

    #[test]
    fn fig6_specialized_renders_per_round_options_and_schedules() {
        use crate::dse::specialize::specialize;
        use crate::estimator::Thresholds;
        use crate::sim::step_network;
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        let dse = crate::dse::brute::explore(&flow, &ARRIA_10_GX1150, Thresholds::default());
        let est = dse.best_estimate.expect("fits");
        let census = step_network(&flow, &ARRIA_10_GX1150, est.fmax_mhz, est.ni, est.nl);
        let spec = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        let sim = spec.analytical_breakdown(&flow, &ARRIA_10_GX1150);
        let t = fig6_specialized(&sim, &spec);
        assert_eq!(t.rows.len(), sim.layers.len());
        let s = t.render();
        assert!(s.contains("Fig. 6 (specialized)"), "{s}");
        assert!(s.contains("slice-resident"), "{s}");
        assert!(s.contains("streamed"), "{s}");
        assert!(s.contains("envelope"), "{s}");
        assert!(s.contains('#'), "{s}");
    }

    #[test]
    fn fig6_bars_monotone_with_time() {
        let sim = alexnet_sim();
        let t = fig6(&sim);
        assert_eq!(t.rows.len(), 8);
        // the longest round gets the longest bar
        let bars: Vec<usize> = t.rows.iter().map(|r| r[4].len()).collect();
        let times: Vec<f64> = sim.layers.iter().map(|l| l.millis).collect();
        let bar_argmax = bars.iter().enumerate().max_by_key(|(_, &b)| b).unwrap().0;
        let t_argmax = times
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(bar_argmax, t_argmax);
    }
}
