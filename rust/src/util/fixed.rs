//! Fixed-point (N, m) arithmetic — the paper's quantized number format.
//!
//! A value is an integer code `N` with implicit scale `2^-m`
//! (real = N * 2^-m, paper §4.2). This module is the Rust twin of
//! `python/compile/kernels/ref.py`'s quantize/dequantize/requantize and is
//! exercised bit-exactly against the golden artifacts in the integration
//! tests.

/// Per-tensor fixed-point format: `bits` total, `m` fractional bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedFormat {
    pub bits: u8,
    pub m: i8,
}

impl FixedFormat {
    pub const fn q8(m: i8) -> Self {
        FixedFormat { bits: 8, m }
    }

    pub fn min_code(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    pub fn max_code(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Resolution (LSB value) of this format.
    pub fn lsb(&self) -> f64 {
        2f64.powi(-(self.m as i32))
    }

    /// Float -> code, round-to-nearest, saturating.
    pub fn quantize(&self, x: f32) -> i64 {
        let scaled = (x as f64 * 2f64.powi(self.m as i32)).round() as i64;
        scaled.clamp(self.min_code(), self.max_code())
    }

    /// Code -> float.
    pub fn dequantize(&self, code: i64) -> f32 {
        (code as f64 * self.lsb()) as f32
    }

    /// Worst-case absolute quantization error inside the representable
    /// range (half an LSB).
    pub fn max_abs_error(&self) -> f64 {
        0.5 * self.lsb()
    }

    /// Representable real range `[lo, hi]`.
    pub fn range(&self) -> (f32, f32) {
        (self.dequantize(self.min_code()), self.dequantize(self.max_code()))
    }
}

/// Rescale an accumulator code with `m_acc` fractional bits to a code with
/// `m_out` fractional bits (arithmetic shift, round-half-up, saturate to
/// `bits`). Matches `ref.requantize` bit-for-bit — the inter-stage step of
/// the FPGA datapath.
pub fn requantize(acc: i64, m_acc: i8, m_out: i8, bits: u8) -> i64 {
    let shift = m_acc as i32 - m_out as i32;
    let rounded = if shift > 0 {
        (acc + (1i64 << (shift - 1))) >> shift
    } else if shift < 0 {
        acc << (-shift)
    } else {
        acc
    };
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    rounded.clamp(lo, hi)
}

/// Quantize a float tensor to int8 codes.
pub fn quantize_tensor(xs: &[f32], m: i8) -> Vec<i8> {
    let f = FixedFormat::q8(m);
    xs.iter().map(|&x| f.quantize(x) as i8).collect()
}

/// Dequantize int8 codes back to floats.
pub fn dequantize_tensor(codes: &[i8], m: i8) -> Vec<f32> {
    let f = FixedFormat::q8(m);
    codes.iter().map(|&c| f.dequantize(c as i64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_saturates() {
        let f = FixedFormat::q8(4);
        assert_eq!(f.quantize(1000.0), 127);
        assert_eq!(f.quantize(-1000.0), -128);
    }

    #[test]
    fn roundtrip_error_bounded() {
        let f = FixedFormat::q8(5);
        for i in -100..100 {
            let x = i as f32 * 0.037;
            let (lo, hi) = f.range();
            if x > lo && x < hi {
                let err = (f.dequantize(f.quantize(x)) - x).abs() as f64;
                assert!(err <= f.max_abs_error() + 1e-9, "x={x} err={err}");
            }
        }
    }

    #[test]
    fn requantize_matches_python_semantics() {
        // mirrored cases from ref.requantize
        assert_eq!(requantize(100, 9, 3, 8), 2); // (100 + 32) >> 6
        assert_eq!(requantize(-100, 9, 3, 8), -2); // arithmetic shift floors
        assert_eq!(requantize(5, 3, 5, 8), 20); // left shift
        assert_eq!(requantize(1 << 20, 4, 4, 8), 127); // saturate hi
        assert_eq!(requantize(-(1 << 20), 4, 4, 8), -128); // saturate lo
    }

    #[test]
    fn requantize_monotone() {
        let mut prev = i64::MIN;
        for acc in (-5000..5000).step_by(7) {
            let q = requantize(acc, 10, 2, 8);
            assert!(q >= prev);
            prev = q;
        }
    }

    #[test]
    fn tensor_helpers_roundtrip() {
        let xs = vec![0.0f32, 0.5, -0.25, 3.9, -4.0];
        let q = quantize_tensor(&xs, 5);
        let d = dequantize_tensor(&q, 5);
        for (x, y) in xs.iter().zip(&d) {
            assert!((x - y).abs() <= 0.5 / 32.0 + 1e-6);
        }
    }
}
