//! Plain-text table rendering for reports and the paper-table benches.
//!
//! Every bench prints the same rows the paper reports; this module keeps
//! the formatting in one place so Table 1-4 and Fig. 6 outputs are
//! uniform and diffable.

/// A simple column-aligned table with a title and optional footnote.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnote: Option<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnote: None,
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn footnote(&mut self, note: impl Into<String>) -> &mut Self {
        self.footnote = Some(note.into());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        if let Some(note) = &self.footnote {
            out.push_str(&format!("  note: {note}\n"));
        }
        out
    }
}

/// Format seconds with an adaptive unit, the way the paper mixes ms/s.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1} hrs", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{:.2} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.1} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

/// Format a count with K/M suffix (resource tables: "427 K ALMs").
pub fn fmt_count(n: f64) -> String {
    if n >= 1e6 {
        format!("{:.1} M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.0} K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.rows_str(&["x", "y"]).rows_str(&["longer", "z"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.rows_str(&["only-one"]);
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(2.0 * 3600.0), "2.0 hrs");
        assert_eq!(fmt_duration(150.0), "2.5 min");
        assert_eq!(fmt_duration(1.5), "1.50 s");
        assert_eq!(fmt_duration(0.018), "18.0 ms");
        assert_eq!(fmt_duration(5e-6), "5.0 µs");
    }

    #[test]
    fn count_units() {
        assert_eq!(fmt_count(427_000.0), "427 K");
        assert_eq!(fmt_count(55.5e6), "55.5 M");
        assert_eq!(fmt_count(83.0), "83");
    }
}
