//! Deterministic FNV-1a hashing for structural fingerprints.
//!
//! The dse::eval memo cache keys estimator results on (model, device,
//! N_i, N_l); the model/device components are FNV-1a folds over their
//! structural census. FNV is used instead of `DefaultHasher` because its
//! output is stable across processes and std versions, which keeps cache
//! statistics reproducible in tests and future on-disk cache formats
//! stable.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one byte into a running FNV-1a hash.
pub fn fold_byte(hash: u64, byte: u8) -> u64 {
    (hash ^ byte as u64).wrapping_mul(FNV_PRIME)
}

/// Fold a byte slice into a running FNV-1a hash.
pub fn fold_bytes(hash: u64, bytes: &[u8]) -> u64 {
    bytes.iter().fold(hash, |h, &b| fold_byte(h, b))
}

/// Fold one little-endian u64 word into a running FNV-1a hash.
pub fn fold_u64(hash: u64, word: u64) -> u64 {
    fold_bytes(hash, &word.to_le_bytes())
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fold_bytes(FNV_OFFSET, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn word_fold_is_order_sensitive() {
        let a = fold_u64(fold_u64(FNV_OFFSET, 1), 2);
        let b = fold_u64(fold_u64(FNV_OFFSET, 2), 1);
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic() {
        assert_eq!(fnv1a(b"cnn2gate"), fnv1a(b"cnn2gate"));
    }
}
