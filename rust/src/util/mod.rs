//! Substrate utilities the offline crate set forces in-tree: JSON codec,
//! PRNG, fixed-point arithmetic, table formatting (see DESIGN.md §2).

pub mod fixed;
pub mod hash;
pub mod json;
pub mod rng;
pub mod sync;
pub mod table;
