//! Deterministic PRNG substrate (the offline image has no `rand` crate).
//!
//! SplitMix64 for seeding + xoshiro256++ for the stream — the standard
//! pairing with solid statistical properties and trivially reproducible
//! runs, which the RL-DSE agent, the synthetic weight generator and the
//! property-test kit all depend on.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into 256 bits of state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive (widened internally, so the full
    /// i64 range is valid).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        if span > u64::MAX as u128 {
            return self.next_u64() as i64; // full-range request
        }
        (lo as i128 + self.below(span as u64) as i128) as i64
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Standard normal via Box-Muller (sufficient for synthetic weights).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// He-initialized weight tensor of `len` elements with `fan_in`.
    pub fn he_weights(&mut self, len: usize, fan_in: usize) -> Vec<f32> {
        let std = (2.0 / fan_in.max(1) as f64).sqrt();
        (0..len).map(|_| (self.normal() * std) as f32).collect()
    }

    /// f32 tensor with standard-normal entries.
    pub fn tensor_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
