//! Minimal, total JSON codec.
//!
//! The offline crate set has no `serde`, so the model-exchange files
//! (`artifacts/models/*.json`), the AOT manifest and every report the
//! framework emits go through this hand-rolled recursive-descent parser
//! and writer. It implements RFC 8259 minus `\u` surrogate pairs beyond
//! the BMP (sufficient for our ASCII-only schema) and preserves object
//! key order (insertion order) so emitted reports are diff-stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep insertion order via a parallel key list.
    Obj(JsonObj),
}

/// Order-preserving JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

/// Error with byte offset into the source.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(JsonObj::new())
    }

    pub fn from_iter_obj<I: IntoIterator<Item = (String, Json)>>(it: I) -> Json {
        let mut o = JsonObj::new();
        for (k, v) in it {
            o.insert(k, v);
        }
        Json::Obj(o)
    }

    // -- accessors ---------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            // analysis: allow(float-eq, fract() == 0.0 is an exact integrality test, not a tolerance comparison)
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `value["a"]["b"]`-style traversal; returns Null on missing keys so
    /// call sites can chain without unwrapping at every level.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `[1,2]` -> `vec![1, 2]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- parse -------------------------------------------------------------
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- write (compact form comes from the Display impl / to_string) ------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Compact (single-line) serialization; `to_string()` comes with it.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf; degrade loudly-enough
    // analysis: allow(float-eq, fract() == 0.0 is an exact integrality test, not a tolerance comparison)
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Hostile documents may nest arbitrarily deep; the recursive-descent
/// `value()` would otherwise translate attacker-controlled input depth
/// into native stack depth. 128 is far beyond any schema we emit
/// (reports nest < 10 deep) and far below any stack limit.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// Bump the container-nesting depth, rejecting hostile documents
    /// before recursion can overflow the native stack.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            Err(self.err("nesting too deep"))
        } else {
            Ok(())
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.array_body();
        self.depth -= 1;
        v
    }

    fn array_body(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.object_body();
        self.depth -= 1;
        v
    }

    fn object_body(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = JsonObj::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // reassemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.25", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,]", "{\"a\"}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(src).is_err(), "{src:?} should fail");
        }
    }

    #[test]
    fn unicode_escapes_and_multibyte() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☕"));
    }

    #[test]
    fn integers_survive_exactly() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.as_i64(), Some(123456789012));
        assert_eq!(v.to_string(), "123456789012");
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn get_chains_total() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("missing").get("deeper").idx(3).is_null());
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // comfortably inside the limit: parses fine
        let deep_ok = format!("{}null{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        // past the limit: a clean error, not a stack overflow
        let deep_arr = format!("{}null{}", "[".repeat(100_000), "]".repeat(100_000));
        let err = Json::parse(&deep_arr).unwrap_err();
        assert!(err.message.contains("nesting too deep"), "{err}");
        let deep_obj = format!("{}0{}", "{\"k\":".repeat(100_000), "}".repeat(100_000));
        let err = Json::parse(&deep_obj).unwrap_err();
        assert!(err.message.contains("nesting too deep"), "{err}");
        // depth counts *nesting*, not total container count: a long flat
        // array of shallow objects is fine at any length
        let flat = format!("[{}{{}}]", "{},".repeat(500));
        assert!(Json::parse(&flat).is_ok());
    }
}
