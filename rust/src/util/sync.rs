//! Poison-tolerant mutex acquisition for the crate's internal locks.

use std::sync::{Mutex, MutexGuard};

/// Acquire `m`, recovering the guard if a previous holder panicked.
///
/// Every mutex in this crate guards plain data (work-stealing deques,
/// the evaluation memo map, channel senders) whose invariants are
/// restored before the guard drops, so a poisoned lock only ever means
/// "some unrelated worker panicked mid-job". Propagating that panic
/// into the next caller — the service daemon, a clean sweep sharing the
/// cache — would turn one bad job into a crashed process, so we take
/// the data as-is instead. This is also what keeps lock acquisition
/// panic-free under the repo lint (`cargo run -p analysis`).
pub fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_a_poisoned_lock() {
        let m = Mutex::new(41usize);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        *locked(&m) += 1;
        assert_eq!(*locked(&m), 42);
    }
}
