//! Model IR: the "linked structure preserving layer order" of paper §4.1.
//!
//! The ONNX front-end parses into [`Graph`]; shape inference
//! ([`shape`]) annotates every edge with its tensor shape using the
//! paper's output-size equations (3)-(4); [`flow`] then extracts the
//! *computation flow* — the fused conv(+relu)(+pool) / fully-connected
//! rounds that the estimator, DSE, simulator and synthesis stages all
//! consume (paper: "we can merge convolution and pooling layers as one
//! layer" — AlexNet becomes 5 fused conv/pool rounds + 3 FC rounds).

pub mod flow;
pub mod graph;
pub mod ops;
pub mod shape;

pub use flow::{ComputationFlow, FusedLayer, LayerKind};
pub use graph::{Graph, Initializer, Node, TensorInfo};
pub use ops::{Attrs, ConvAttrs, DType, Op, PoolAttrs};
pub use shape::{infer_shapes, ShapeError};
