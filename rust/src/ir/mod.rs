//! Model IR: the "linked structure preserving layer order" of paper §4.1.
//!
//! The ONNX front-end parses into [`Graph`]; shape inference
//! ([`shape`]) annotates every edge with its tensor shape using the
//! paper's output-size equations (3)-(4), extended with dilation and
//! channel groups; [`flow`] then extracts the *computation flow* — a
//! DAG of fused rounds the estimator, DSE, simulator and synthesis
//! stages all consume (paper: "we can merge convolution and pooling
//! layers as one layer" — AlexNet becomes 5 fused conv/pool rounds +
//! 3 FC rounds). Every [`FusedLayer`] names its producer rounds, so
//! beyond the linear conv(+relu)(+pool) / FC chains the flow carries
//! ResNet-class residual [`LayerKind::Add`] merges (two feeds,
//! trailing Relu fused in) and MobileNet-class
//! [`LayerKind::DepthwiseConvPool`] rounds (groups == cin); a linear
//! chain is the special case `producers == [i-1]` and takes an
//! unchanged code path.

pub mod flow;
pub mod graph;
pub mod ops;
pub mod shape;

pub use flow::{ComputationFlow, FusedLayer, LayerKind};
pub use graph::{Graph, Initializer, Node, TensorInfo};
pub use ops::{Attrs, ConvAttrs, DType, Op, PoolAttrs};
pub use shape::{infer_shapes, ShapeError};
