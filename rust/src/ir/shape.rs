//! Shape inference over a [`Graph`] — paper equations (3)-(4) propagated
//! node by node. Produces a name -> TensorInfo map used by the flow
//! extractor, the estimator (buffer sizing) and the simulator.

use std::collections::HashMap;

use super::graph::{Graph, TensorInfo};
use super::ops::{DType, Op};

#[derive(Debug, Clone, PartialEq)]
pub struct ShapeError(pub String);

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shape error: {}", self.0)
    }
}

impl std::error::Error for ShapeError {}

/// Infer the shape of every edge. Returns the map and the output shape.
pub fn infer_shapes(g: &Graph) -> Result<HashMap<String, TensorInfo>, ShapeError> {
    let mut shapes: HashMap<String, TensorInfo> = HashMap::new();
    shapes.insert(g.input_name.clone(), g.input.clone());
    for (name, init) in &g.initializers {
        shapes.insert(name.clone(), init.info.clone());
    }
    for (i, node) in g.nodes.iter().enumerate() {
        let get = |name: &str| -> Result<&TensorInfo, ShapeError> {
            shapes
                .get(name)
                .ok_or_else(|| ShapeError(format!("node {i}: unknown tensor '{name}'")))
        };
        let out_info: TensorInfo = match &node.op {
            Op::Conv(attrs) => {
                let x = get(&node.inputs[0])?;
                let w = get(&node.inputs[1])?;
                if x.shape.len() != 3 {
                    return Err(ShapeError(format!(
                        "node {i}: Conv input must be CHW, got {:?}",
                        x.shape
                    )));
                }
                if w.shape.len() != 4 {
                    return Err(ShapeError(format!(
                        "node {i}: Conv weight must be OIHW, got {:?}",
                        w.shape
                    )));
                }
                let (cin, h, win) = (x.shape[0], x.shape[1], x.shape[2]);
                let (cout, wcin, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                let groups = attrs.groups;
                if groups == 0 || cin % groups != 0 || cout % groups != 0 {
                    return Err(ShapeError(format!(
                        "node {i}: Conv group={groups} must divide Cin={cin} and Cout={cout}"
                    )));
                }
                if cin / groups != wcin {
                    return Err(ShapeError(format!(
                        "node {i}: Conv channel mismatch: input Cin={cin} / group={groups}, \
                         weight Cin={wcin}"
                    )));
                }
                if [kh, kw] != attrs.kernel {
                    return Err(ShapeError(format!(
                        "node {i}: kernel_shape {:?} != weight spatial dims [{kh}, {kw}]",
                        attrs.kernel
                    )));
                }
                if let Some(b) = node.inputs.get(2) {
                    let bi = get(b)?;
                    if bi.shape != vec![cout] {
                        return Err(ShapeError(format!(
                            "node {i}: bias shape {:?} != [{cout}]",
                            bi.shape
                        )));
                    }
                }
                let (oh, ow) = attrs.out_hw(h, win).ok_or_else(|| {
                    ShapeError(format!(
                        "node {i}: Conv window {:?} exceeds input {h}x{win}",
                        attrs.kernel
                    ))
                })?;
                TensorInfo {
                    shape: vec![cout, oh, ow],
                    dtype: x.dtype,
                }
            }
            Op::MaxPool(attrs) => {
                let x = get(&node.inputs[0])?;
                if x.shape.len() != 3 {
                    return Err(ShapeError(format!(
                        "node {i}: MaxPool input must be CHW, got {:?}",
                        x.shape
                    )));
                }
                let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                let (oh, ow) = attrs.out_hw(h, w).ok_or_else(|| {
                    ShapeError(format!(
                        "node {i}: MaxPool window {:?} exceeds input {h}x{w}",
                        attrs.kernel
                    ))
                })?;
                TensorInfo {
                    shape: vec![c, oh, ow],
                    dtype: x.dtype,
                }
            }
            Op::Relu | Op::Softmax => get(&node.inputs[0])?.clone(),
            Op::Add => {
                let a = get(&node.inputs[0])?;
                let b = get(&node.inputs[1])?;
                if a.shape != b.shape {
                    return Err(ShapeError(format!(
                        "node {i}: Add operand shapes differ: {:?} vs {:?}",
                        a.shape, b.shape
                    )));
                }
                a.clone()
            }
            Op::GlobalAveragePool => {
                let x = get(&node.inputs[0])?;
                if x.shape.len() != 3 {
                    return Err(ShapeError(format!(
                        "node {i}: GlobalAveragePool input must be CHW, got {:?}",
                        x.shape
                    )));
                }
                TensorInfo {
                    shape: vec![x.shape[0], 1, 1],
                    dtype: x.dtype,
                }
            }
            Op::Flatten => {
                let x = get(&node.inputs[0])?;
                TensorInfo {
                    shape: vec![x.numel()],
                    dtype: x.dtype,
                }
            }
            Op::Gemm { trans_b } => {
                let x = get(&node.inputs[0])?;
                let w = get(&node.inputs[1])?;
                if x.shape.len() != 1 || w.shape.len() != 2 {
                    return Err(ShapeError(format!(
                        "node {i}: Gemm expects vec x matrix, got {:?} x {:?}",
                        x.shape, w.shape
                    )));
                }
                let (n, k) = if *trans_b {
                    (w.shape[0], w.shape[1])
                } else {
                    (w.shape[1], w.shape[0])
                };
                if k != x.shape[0] {
                    return Err(ShapeError(format!(
                        "node {i}: Gemm contraction mismatch: x has {}, W has {k}",
                        x.shape[0]
                    )));
                }
                TensorInfo {
                    shape: vec![n],
                    dtype: x.dtype,
                }
            }
        };
        for output in &node.outputs {
            shapes.insert(output.clone(), out_info.clone());
        }
    }
    if !shapes.contains_key(&g.output_name) {
        return Err(ShapeError(format!(
            "graph output '{}' has no shape",
            g.output_name
        )));
    }
    Ok(shapes)
}

/// Convenience: the inferred output TensorInfo.
pub fn output_info(g: &Graph) -> Result<TensorInfo, ShapeError> {
    let shapes = infer_shapes(g)?;
    Ok(shapes[&g.output_name].clone())
}

/// The largest intermediate activation in elements — drives on-chip buffer
/// sizing in the estimator.
pub fn max_activation_elems(g: &Graph) -> Result<usize, ShapeError> {
    let shapes = infer_shapes(g)?;
    Ok(g
        .nodes
        .iter()
        .flat_map(|n| n.outputs.iter())
        .chain(std::iter::once(&g.input_name))
        .map(|n| shapes[n].numel())
        .max()
        .unwrap_or(0))
}

#[allow(unused)]
fn _dtype_unused(_: DType) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::zoo;

    #[test]
    fn alexnet_shapes_match_paper() {
        let g = zoo::build("alexnet", false).unwrap();
        let shapes = infer_shapes(&g).unwrap();
        // conv1 out 64x55x55, pool1 64x27x27, classifier 1000
        let conv1_out = &g.nodes[0].outputs[0];
        assert_eq!(shapes[conv1_out].shape, vec![64, 55, 55]);
        assert_eq!(shapes[&g.output_name].shape, vec![1000]);
    }

    #[test]
    fn vgg16_output_is_1000() {
        let g = zoo::build("vgg16", false).unwrap();
        assert_eq!(output_info(&g).unwrap().shape, vec![1000]);
    }

    #[test]
    fn mismatched_channels_rejected() {
        let mut g = zoo::build("tiny", true).unwrap();
        // corrupt the first conv weight's Cin
        let wname = g.nodes[0].inputs[1].clone();
        g.initializers.get_mut(&wname).unwrap().info.shape[1] = 7;
        assert!(infer_shapes(&g).is_err());
    }

    #[test]
    fn max_activation_is_input_or_bigger() {
        let g = zoo::build("vgg16", false).unwrap();
        let m = max_activation_elems(&g).unwrap();
        // VGG block1 keeps 224x224 at 64 channels: 3.2M elements
        assert_eq!(m, 64 * 224 * 224);
    }

    #[test]
    fn grouped_conv_checks_the_per_group_weight_cin() {
        use crate::ir::graph::{Initializer, Node};
        use crate::ir::ops::ConvAttrs;
        use std::collections::HashMap;
        let build = |groups: usize, wcin: usize| {
            let mut attrs = ConvAttrs::unit([3, 3]);
            attrs.pads = [1, 1];
            attrs.groups = groups;
            let mut initializers = HashMap::new();
            initializers.insert(
                "w".to_string(),
                Initializer {
                    info: TensorInfo {
                        shape: vec![8, wcin, 3, 3],
                        dtype: DType::F32,
                    },
                    data: None,
                },
            );
            Graph {
                name: "g".into(),
                input_name: "input".into(),
                input: TensorInfo {
                    shape: vec![8, 6, 6],
                    dtype: DType::F32,
                },
                output_name: "y".into(),
                nodes: vec![Node {
                    op: Op::Conv(attrs),
                    inputs: vec!["input".into(), "w".into()],
                    outputs: vec!["y".into()],
                }],
                initializers,
            }
        };
        // dense: wcin == cin; grouped: wcin == cin/groups; depthwise: 1
        for (groups, wcin) in [(1, 8), (4, 2), (8, 1)] {
            let shapes = infer_shapes(&build(groups, wcin)).unwrap();
            assert_eq!(shapes["y"].shape, vec![8, 6, 6], "groups={groups}");
        }
        // wrong per-group Cin, a group that doesn't divide, and group 0
        assert!(infer_shapes(&build(4, 8)).is_err());
        assert!(infer_shapes(&build(3, 2)).is_err());
        assert!(infer_shapes(&build(0, 8)).is_err());
    }

    #[test]
    fn add_and_gap_shapes() {
        use crate::ir::graph::Node;
        use crate::ir::ops::ConvAttrs;
        use std::collections::HashMap;
        // input -> conv(1x1, identity channel count) -> add(input, conv) -> gap
        let mut initializers = HashMap::new();
        initializers.insert(
            "w".to_string(),
            crate::ir::graph::Initializer {
                info: TensorInfo {
                    shape: vec![4, 4, 1, 1],
                    dtype: DType::F32,
                },
                data: None,
            },
        );
        let g = Graph {
            name: "res".into(),
            input_name: "input".into(),
            input: TensorInfo {
                shape: vec![4, 5, 5],
                dtype: DType::F32,
            },
            output_name: "gap".into(),
            nodes: vec![
                Node {
                    op: Op::Conv(ConvAttrs::unit([1, 1])),
                    inputs: vec!["input".into(), "w".into()],
                    outputs: vec!["c".into()],
                },
                Node {
                    op: Op::Add,
                    inputs: vec!["input".into(), "c".into()],
                    outputs: vec!["s".into()],
                },
                Node {
                    op: Op::GlobalAveragePool,
                    inputs: vec!["s".into()],
                    outputs: vec!["gap".into()],
                },
            ],
            initializers,
        };
        let shapes = infer_shapes(&g).unwrap();
        assert_eq!(shapes["s"].shape, vec![4, 5, 5]);
        assert_eq!(shapes["gap"].shape, vec![4, 1, 1]);
        // mismatched Add operands are rejected
        let mut bad = g.clone();
        bad.initializers.get_mut("w").unwrap().info.shape = vec![8, 4, 1, 1];
        let err = infer_shapes(&bad).unwrap_err();
        assert!(err.0.contains("Add operand shapes differ"), "{err}");
    }
}
