//! Graph container: nodes in topological (file) order, named edges,
//! initializers with optionally-resident data (ONNX external-data style).

use std::collections::HashMap;

use super::ops::{DType, Op};

/// Shape + dtype of a named tensor edge.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorInfo {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

/// A learned tensor. `data` is `None` when the model file declares the
/// initializer but carries no external data (large zoo models) — the
/// coordinator then materializes synthetic weights on demand.
#[derive(Debug, Clone)]
pub struct Initializer {
    pub info: TensorInfo,
    pub data: Option<Vec<f32>>,
}

/// One operator application.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// A parsed model graph.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub input_name: String,
    pub input: TensorInfo,
    pub output_name: String,
    pub nodes: Vec<Node>,
    pub initializers: HashMap<String, Initializer>,
}

impl Graph {
    /// Total learned parameters.
    pub fn param_count(&self) -> usize {
        self.initializers.values().map(|i| i.info.numel()).sum()
    }

    /// Parameter bytes at a given precision (the paper quotes 8-bit).
    pub fn param_bytes(&self, dtype: DType) -> usize {
        self.param_count() * dtype.size_bytes()
    }

    /// Whether every initializer has resident data.
    pub fn has_weights(&self) -> bool {
        self.initializers.values().all(|i| i.data.is_some())
    }

    /// Names of node ops in order (handy for tests / reports).
    pub fn op_names(&self) -> Vec<&'static str> {
        self.nodes.iter().map(|n| n.op.name()).collect()
    }

    /// Structural validation: every node input is either the graph input,
    /// an initializer, or a previous node's output; the declared graph
    /// output is produced; names are unique.
    pub fn validate(&self) -> Result<(), String> {
        let mut known: HashMap<&str, ()> = HashMap::new();
        known.insert(self.input_name.as_str(), ());
        for k in self.initializers.keys() {
            known.insert(k.as_str(), ());
        }
        for (i, node) in self.nodes.iter().enumerate() {
            for input in &node.inputs {
                if !known.contains_key(input.as_str()) {
                    return Err(format!(
                        "node {i} ({}) consumes undefined tensor '{input}'",
                        node.op.name()
                    ));
                }
            }
            for output in &node.outputs {
                if known.contains_key(output.as_str()) {
                    return Err(format!(
                        "node {i} ({}) redefines tensor '{output}'",
                        node.op.name()
                    ));
                }
                known.insert(output.as_str(), ());
            }
        }
        if !known.contains_key(self.output_name.as_str()) {
            return Err(format!(
                "graph output '{}' is never produced",
                self.output_name
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ops::ConvAttrs;

    fn tiny_graph() -> Graph {
        let mut initializers = HashMap::new();
        initializers.insert(
            "w".to_string(),
            Initializer {
                info: TensorInfo {
                    shape: vec![4, 1, 3, 3],
                    dtype: DType::F32,
                },
                data: Some(vec![0.0; 36]),
            },
        );
        Graph {
            name: "t".into(),
            input_name: "input".into(),
            input: TensorInfo {
                shape: vec![1, 8, 8],
                dtype: DType::F32,
            },
            output_name: "y".into(),
            nodes: vec![Node {
                op: Op::Conv(ConvAttrs::unit([3, 3])),
                inputs: vec!["input".into(), "w".into()],
                outputs: vec!["y".into()],
            }],
            initializers,
        }
    }

    #[test]
    fn validate_accepts_wellformed() {
        assert_eq!(tiny_graph().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_undefined_input() {
        let mut g = tiny_graph();
        g.nodes[0].inputs[1] = "missing".into();
        assert!(g.validate().unwrap_err().contains("undefined tensor"));
    }

    #[test]
    fn validate_rejects_missing_output() {
        let mut g = tiny_graph();
        g.output_name = "nope".into();
        assert!(g.validate().unwrap_err().contains("never produced"));
    }

    #[test]
    fn validate_rejects_redefinition() {
        let mut g = tiny_graph();
        let dup = g.nodes[0].clone();
        g.nodes.push(dup);
        assert!(g.validate().unwrap_err().contains("redefines"));
    }

    #[test]
    fn param_census() {
        let g = tiny_graph();
        assert_eq!(g.param_count(), 36);
        assert_eq!(g.param_bytes(DType::I8), 36);
        assert!(g.has_weights());
    }
}
