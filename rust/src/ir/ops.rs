//! Operator set of paper §4.1: Conv, MaxPool, Relu, Gemm, Softmax (+
//! Flatten, which ONNX inserts before Gemm), extended with the
//! branch-family ops (Add, GlobalAveragePool) and grouped/dilated Conv
//! that ResNet/MobileNet-class graphs require.

use std::fmt;

/// Element type of a tensor edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "int8" | "i8" => Some(DType::I8),
            "int32" | "i32" => Some(DType::I32),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I8 => "int8",
            DType::I32 => "int32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Conv attributes exactly as the paper's parser extracts them
/// ("dilations, pads, kernel shape, and stride"), plus ONNX `group`:
/// `groups == 1` is a dense conv, `groups == cin` a depthwise conv, and
/// anything between a grouped conv (MACs and weights scale by
/// `cin·cout/groups·k²`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvAttrs {
    pub kernel: [usize; 2],
    pub strides: [usize; 2],
    /// Symmetric (h, w) padding. ONNX 4-element pads are validated to be
    /// symmetric by the parser and folded to 2.
    pub pads: [usize; 2],
    pub dilations: [usize; 2],
    /// ONNX `group`: input channels are split into `groups` slices, each
    /// convolved with its own `cout/groups` filters.
    pub groups: usize,
}

impl ConvAttrs {
    pub fn unit(kernel: [usize; 2]) -> Self {
        ConvAttrs {
            kernel,
            strides: [1, 1],
            pads: [0, 0],
            dilations: [1, 1],
            groups: 1,
        }
    }

    /// Paper equation (3): floor((in + 2p - d(k-1) - 1)/s + 1).
    pub fn out_hw(&self, h: usize, w: usize) -> Option<(usize, usize)> {
        let dim = |x: usize, i: usize| -> Option<usize> {
            let num = (x + 2 * self.pads[i])
                .checked_sub(self.dilations[i] * (self.kernel[i] - 1) + 1)?;
            Some(num / self.strides[i] + 1)
        };
        Some((dim(h, 0)?, dim(w, 1)?))
    }
}

/// MaxPool attributes. Dilation participates in the output-size
/// equation exactly as for Conv (a parsed dilated MaxPool must not
/// silently compute the undilated window).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolAttrs {
    pub kernel: [usize; 2],
    pub strides: [usize; 2],
    pub pads: [usize; 2],
    pub dilations: [usize; 2],
}

impl PoolAttrs {
    pub fn out_hw(&self, h: usize, w: usize) -> Option<(usize, usize)> {
        ConvAttrs {
            kernel: self.kernel,
            strides: self.strides,
            pads: self.pads,
            dilations: self.dilations,
            groups: 1,
        }
        .out_hw(h, w)
    }
}

/// A node's operator.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Conv(ConvAttrs),
    MaxPool(PoolAttrs),
    Relu,
    Flatten,
    /// Fully connected layer; `trans_b` mirrors ONNX Gemm's transB.
    Gemm {
        trans_b: bool,
    },
    Softmax,
    /// Element-wise residual join of two equal-shape tensors.
    Add,
    /// Spatial mean over the full (h, w) plane: [c, h, w] -> [c, 1, 1].
    GlobalAveragePool,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Conv(_) => "Conv",
            Op::MaxPool(_) => "MaxPool",
            Op::Relu => "Relu",
            Op::Flatten => "Flatten",
            Op::Gemm { .. } => "Gemm",
            Op::Softmax => "Softmax",
            Op::Add => "Add",
            Op::GlobalAveragePool => "GlobalAveragePool",
        }
    }
}

/// Raw attribute bag used during parsing before validation.
#[derive(Debug, Clone, Default)]
pub struct Attrs {
    pub kernel_shape: Option<Vec<usize>>,
    pub strides: Option<Vec<usize>>,
    pub pads: Option<Vec<usize>>,
    pub dilations: Option<Vec<usize>>,
    pub group: Option<usize>,
    pub trans_b: Option<bool>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_matches_paper_examples() {
        // AlexNet conv1: 224, k11, s4, p2 -> 55
        let a = ConvAttrs {
            kernel: [11, 11],
            strides: [4, 4],
            pads: [2, 2],
            dilations: [1, 1],
            groups: 1,
        };
        assert_eq!(a.out_hw(224, 224), Some((55, 55)));
        // VGG 3x3 s1 p1 preserves size
        let v = ConvAttrs {
            kernel: [3, 3],
            strides: [1, 1],
            pads: [1, 1],
            dilations: [1, 1],
            groups: 1,
        };
        assert_eq!(v.out_hw(224, 224), Some((224, 224)));
        // dilation shrinks the effective window
        let d = ConvAttrs {
            kernel: [3, 3],
            strides: [1, 1],
            pads: [0, 0],
            dilations: [2, 2],
            groups: 1,
        };
        assert_eq!(d.out_hw(10, 10), Some((6, 6)));
    }

    #[test]
    fn conv_out_none_when_window_exceeds_input() {
        let a = ConvAttrs::unit([7, 7]);
        assert_eq!(a.out_hw(3, 3), None);
        assert_eq!(a.groups, 1, "unit() is a dense conv");
    }

    #[test]
    fn grouped_conv_shares_the_window_math() {
        // groups only reshapes the weight tensor; the spatial equation
        // is untouched
        let mut g = ConvAttrs::unit([3, 3]);
        g.groups = 4;
        assert_eq!(g.out_hw(8, 8), ConvAttrs::unit([3, 3]).out_hw(8, 8));
    }

    #[test]
    fn pool_out_overlapping() {
        // AlexNet pool 3/2: 55 -> 27
        let p = PoolAttrs {
            kernel: [3, 3],
            strides: [2, 2],
            pads: [0, 0],
            dilations: [1, 1],
        };
        assert_eq!(p.out_hw(55, 55), Some((27, 27)));
    }

    #[test]
    fn dilated_pool_shrinks_the_window() {
        // a dilated MaxPool widens the effective kernel: k3 d2 covers 5
        let p = PoolAttrs {
            kernel: [3, 3],
            strides: [1, 1],
            pads: [0, 0],
            dilations: [2, 2],
        };
        assert_eq!(p.out_hw(10, 10), Some((6, 6)));
        // and an oversized dilated window is a shape error, not a wrap
        assert_eq!(p.out_hw(4, 4), None);
    }

    #[test]
    fn dtype_parse_roundtrip() {
        for d in [DType::F32, DType::I8, DType::I32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("float64"), None);
    }
}
