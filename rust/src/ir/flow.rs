//! Computation-flow extraction (paper §4.1 / §5).
//!
//! The pipelined kernel architecture executes the network as a sequence
//! of *rounds*: each round is one pass of {mem_read -> conv lanes ->
//! pool -> mem_write}. A Conv followed by Relu and/or MaxPool fuses into
//! one round (pool configured as pass-through when absent); a Gemm runs
//! on the same lane array with the pool stage passing through
//! (paper §3.2.3 / §5). AlexNet therefore becomes 5 fused conv/pool
//! rounds + 3 FC rounds — exactly the 8 bars of the paper's Fig. 6.
//!
//! Beyond strict chains, the flow is a **DAG of rounds**: every
//! [`FusedLayer`] carries the indices of the rounds that produce its
//! feed streams ([`FusedLayer::producers`]), so residual topologies
//! (ResNet basic blocks) become [`LayerKind::Add`] merge rounds with two
//! producers, and depthwise convolutions (MobileNet separable stacks)
//! become [`LayerKind::DepthwiseConvPool`] rounds whose reduction dim is
//! the k×k window alone. Linear chains extract exactly as before: each
//! round's producer list is `[index - 1]` (empty for the input round)
//! and the fingerprint folds the same words, so AlexNet/VGG cache keys
//! and goldens are byte-identical to the chain-era extractor.

use std::collections::HashMap;

use super::graph::{Graph, Node};
use super::ops::{ConvAttrs, Op, PoolAttrs};
use super::shape::{infer_shapes, ShapeError};

/// One fused pipeline round.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    ConvPool {
        conv: ConvAttrs,
        cin: usize,
        cout: usize,
        in_hw: (usize, usize),
        conv_out_hw: (usize, usize),
        relu: bool,
        pool: Option<PoolAttrs>,
        /// Spatial size after the (optional) pool stage.
        out_hw: (usize, usize),
    },
    /// Depthwise conv round (`groups == cin == cout`): each channel is
    /// convolved with its own k×k filter, so the lane array reduces over
    /// the window alone and the weight tensor is `channels·k²`.
    DepthwiseConvPool {
        conv: ConvAttrs,
        channels: usize,
        in_hw: (usize, usize),
        conv_out_hw: (usize, usize),
        relu: bool,
        pool: Option<PoolAttrs>,
        out_hw: (usize, usize),
    },
    /// Element-wise residual join on the write-back path: two producer
    /// rounds feed one round that adds them (and optionally applies the
    /// trailing Relu) — no weights, reduction dim 1.
    Add {
        channels: usize,
        hw: (usize, usize),
        relu: bool,
    },
    Fc {
        in_features: usize,
        out_features: usize,
        relu: bool,
    },
}

/// A fused layer with its cost census.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedLayer {
    pub index: usize,
    /// Round indices producing this round's feed streams, in feed order
    /// (an [`LayerKind::Add`] round lists feed A then feed B). Empty
    /// means the round reads the graph input; a linear chain is
    /// `[index - 1]`.
    pub producers: Vec<usize>,
    pub kind: LayerKind,
}

impl FusedLayer {
    /// Multiply-accumulates in this round (the conv/FC dominates; pool
    /// comparisons are not MACs, the Add's element-wise sums count one
    /// op per element).
    pub fn macs(&self) -> u64 {
        match &self.kind {
            LayerKind::ConvPool {
                conv,
                cin,
                cout,
                conv_out_hw,
                ..
            } => {
                (conv_out_hw.0 * conv_out_hw.1 * cout * (cin / conv.groups)
                    * conv.kernel[0]
                    * conv.kernel[1]) as u64
            }
            LayerKind::DepthwiseConvPool {
                conv,
                channels,
                conv_out_hw,
                ..
            } => {
                (conv_out_hw.0 * conv_out_hw.1 * channels * conv.kernel[0] * conv.kernel[1])
                    as u64
            }
            LayerKind::Add { channels, hw, .. } => (channels * hw.0 * hw.1) as u64,
            LayerKind::Fc {
                in_features,
                out_features,
                ..
            } => (*in_features * *out_features) as u64,
        }
    }

    /// Reduction-dimension length fed to the lane array (Cin/g·KH·KW for
    /// conv rounds, KH·KW for depthwise rounds, K for FC rounds, 1 for
    /// Add merges) — the axis the `N_i` vectors tile.
    pub fn reduction_dim(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { conv, cin, .. } => {
                (cin / conv.groups) * conv.kernel[0] * conv.kernel[1]
            }
            LayerKind::DepthwiseConvPool { conv, .. } => conv.kernel[0] * conv.kernel[1],
            LayerKind::Add { .. } => 1,
            LayerKind::Fc { in_features, .. } => *in_features,
        }
    }

    /// Output features produced by the lane array (`N_l` tiles this axis).
    pub fn out_features(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cout, .. } => *cout,
            LayerKind::DepthwiseConvPool { channels, .. } => *channels,
            LayerKind::Add { channels, .. } => *channels,
            LayerKind::Fc { out_features, .. } => *out_features,
        }
    }

    /// Output "pixels" per feature (1 for FC rounds).
    pub fn out_pixels(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { conv_out_hw, .. } => conv_out_hw.0 * conv_out_hw.1,
            LayerKind::DepthwiseConvPool { conv_out_hw, .. } => conv_out_hw.0 * conv_out_hw.1,
            LayerKind::Add { hw, .. } => hw.0 * hw.1,
            LayerKind::Fc { .. } => 1,
        }
    }

    /// Weight elements this round streams from memory (grouped convs
    /// scale by 1/groups; Add merges carry none).
    pub fn weight_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool {
                conv, cin, cout, ..
            } => cout * (cin / conv.groups) * conv.kernel[0] * conv.kernel[1] + cout,
            LayerKind::DepthwiseConvPool { conv, channels, .. } => {
                channels * conv.kernel[0] * conv.kernel[1] + channels
            }
            LayerKind::Add { .. } => 0,
            LayerKind::Fc {
                in_features,
                out_features,
                ..
            } => in_features * out_features + out_features,
        }
    }

    /// Input activation elements this round reads (an Add reads both
    /// operand streams).
    pub fn input_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cin, in_hw, .. } => cin * in_hw.0 * in_hw.1,
            LayerKind::DepthwiseConvPool {
                channels, in_hw, ..
            } => channels * in_hw.0 * in_hw.1,
            LayerKind::Add { channels, hw, .. } => 2 * channels * hw.0 * hw.1,
            LayerKind::Fc { in_features, .. } => *in_features,
        }
    }

    /// Output activation elements this round writes (after pool).
    pub fn output_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cout, out_hw, .. } => cout * out_hw.0 * out_hw.1,
            LayerKind::DepthwiseConvPool {
                channels, out_hw, ..
            } => channels * out_hw.0 * out_hw.1,
            LayerKind::Add { channels, hw, .. } => channels * hw.0 * hw.1,
            LayerKind::Fc { out_features, .. } => *out_features,
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::ConvPool { .. } | LayerKind::DepthwiseConvPool { .. }
        )
    }

    /// Depthwise rounds reduce over k² alone (9 for the ubiquitous 3×3),
    /// which no power-of-two `N_i` divides — the divisor constraints and
    /// the specialization pass both exempt them (padding via `div_ceil`,
    /// as FC rounds always have).
    pub fn is_depthwise(&self) -> bool {
        matches!(self.kind, LayerKind::DepthwiseConvPool { .. })
    }

    /// Whether the round streams a weight tensor (everything except the
    /// Add merge) — gates weight DDR traffic and the slice-resident
    /// schedule.
    pub fn has_weights(&self) -> bool {
        !matches!(self.kind, LayerKind::Add { .. })
    }

    /// Whether this round's feed wiring is the linear-chain default:
    /// round 0 reads the graph input, round i reads round i-1.
    pub fn linear_producers(&self) -> bool {
        if self.index == 0 {
            self.producers.is_empty()
        } else {
            self.producers.as_slice() == [self.index - 1]
        }
    }

    /// Structural kind tag for the fingerprint: 0 for the chain-era
    /// kinds (dense conv, FC), nonzero for the branch-family extensions.
    fn kind_tag(&self) -> u64 {
        match &self.kind {
            LayerKind::ConvPool { conv, .. } => u64::from(conv.groups > 1),
            LayerKind::DepthwiseConvPool { .. } => 2,
            LayerKind::Add { .. } => 3,
            LayerKind::Fc { .. } => 0,
        }
    }

    /// Human-readable round label ("L2 conv+pool", "L6 fc") — shared by
    /// the latency breakdown, the stepped census and the specialization
    /// table so their rows align textually.
    pub fn label(&self) -> String {
        match &self.kind {
            LayerKind::ConvPool { pool, .. } => {
                if pool.is_some() {
                    format!("L{} conv+pool", self.index + 1)
                } else {
                    format!("L{} conv", self.index + 1)
                }
            }
            LayerKind::DepthwiseConvPool { pool, .. } => {
                if pool.is_some() {
                    format!("L{} dwconv+pool", self.index + 1)
                } else {
                    format!("L{} dwconv", self.index + 1)
                }
            }
            LayerKind::Add { .. } => format!("L{} add", self.index + 1),
            LayerKind::Fc { .. } => format!("L{} fc", self.index + 1),
        }
    }
}

/// The extracted computation flow of a model.
#[derive(Debug, Clone)]
pub struct ComputationFlow {
    pub model_name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<FusedLayer>,
    pub has_softmax: bool,
}

impl ComputationFlow {
    /// Extract from a validated, shape-inferred graph.
    ///
    /// Fusion safety on a DAG: a trailing Relu/MaxPool folds into the
    /// producing round only when it is the *sole* consumer of that
    /// round's output (first input, consumer count 1, not the graph
    /// output) — on a residual branch the pre-activation tensor also
    /// feeds the skip Add, so it must stay a round boundary. Linear
    /// chains satisfy the condition trivially and fuse exactly as the
    /// chain-era extractor did.
    pub fn extract(g: &Graph) -> Result<ComputationFlow, ShapeError> {
        g.validate().map_err(ShapeError)?;
        let shapes = infer_shapes(g)?;
        // consumer counts decide fusion safety; origin maps a tensor
        // name to the round that produces it (None: the graph input)
        let mut consumers: HashMap<&str, usize> = HashMap::new();
        for node in &g.nodes {
            for input in &node.inputs {
                *consumers.entry(input.as_str()).or_insert(0) += 1;
            }
        }
        let fusable = |out: &str, next: &Node| -> bool {
            next.inputs.first().map(String::as_str) == Some(out)
                && consumers.get(out).copied().unwrap_or(0) == 1
                && out != g.output_name
        };
        let mut origin: HashMap<String, Option<usize>> = HashMap::new();
        origin.insert(g.input_name.clone(), None);
        let feed = |origin: &HashMap<String, Option<usize>>, names: &[&String]| -> Vec<usize> {
            names.iter().filter_map(|n| origin.get(n.as_str()).copied().flatten()).collect()
        };
        let mut layers: Vec<FusedLayer> = Vec::new();
        let mut has_softmax = false;
        let mut i = 0;
        while i < g.nodes.len() {
            let node = &g.nodes[i];
            match &node.op {
                Op::Conv(attrs) => {
                    let x = &shapes[&node.inputs[0]];
                    let (cin, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let conv_out = &shapes[&node.outputs[0]];
                    let cout = conv_out.shape[0];
                    let conv_out_hw = (conv_out.shape[1], conv_out.shape[2]);
                    let producers = feed(&origin, &[&node.inputs[0]]);
                    let mut relu = false;
                    let mut pool = None;
                    let mut out_hw = conv_out_hw;
                    let mut out_name = &node.outputs[0];
                    let mut j = i + 1;
                    if let Some(n) = g.nodes.get(j) {
                        if matches!(n.op, Op::Relu) && fusable(out_name, n) {
                            relu = true;
                            out_name = &n.outputs[0];
                            j += 1;
                        }
                    }
                    if let Some(n) = g.nodes.get(j) {
                        if let Op::MaxPool(pattrs) = &n.op {
                            if fusable(out_name, n) {
                                pool = Some(*pattrs);
                                let po = &shapes[&n.outputs[0]];
                                out_hw = (po.shape[1], po.shape[2]);
                                out_name = &n.outputs[0];
                                j += 1;
                            }
                        }
                    }
                    let index = layers.len();
                    let kind = if attrs.groups == cin && cout == cin {
                        LayerKind::DepthwiseConvPool {
                            conv: *attrs,
                            channels: cin,
                            in_hw: (h, w),
                            conv_out_hw,
                            relu,
                            pool,
                            out_hw,
                        }
                    } else {
                        LayerKind::ConvPool {
                            conv: *attrs,
                            cin,
                            cout,
                            in_hw: (h, w),
                            conv_out_hw,
                            relu,
                            pool,
                            out_hw,
                        }
                    };
                    layers.push(FusedLayer {
                        index,
                        producers,
                        kind,
                    });
                    origin.insert(out_name.clone(), Some(index));
                    i = j;
                }
                Op::MaxPool(pattrs) => {
                    // standalone pool (no preceding fusable conv): model
                    // it as a pass-through conv round with a 1x1 identity
                    let x = &shapes[&node.inputs[0]];
                    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let po = &shapes[&node.outputs[0]];
                    let index = layers.len();
                    layers.push(FusedLayer {
                        index,
                        producers: feed(&origin, &[&node.inputs[0]]),
                        kind: LayerKind::ConvPool {
                            conv: ConvAttrs::unit([1, 1]),
                            cin: c,
                            cout: c,
                            in_hw: (h, w),
                            conv_out_hw: (h, w),
                            relu: false,
                            pool: Some(*pattrs),
                            out_hw: (po.shape[1], po.shape[2]),
                        },
                    });
                    origin.insert(node.outputs[0].clone(), Some(index));
                    i += 1;
                }
                Op::GlobalAveragePool => {
                    // spatial mean over the full plane: a pass-through
                    // conv round whose pool window is the whole (h, w)
                    let x = &shapes[&node.inputs[0]];
                    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let index = layers.len();
                    layers.push(FusedLayer {
                        index,
                        producers: feed(&origin, &[&node.inputs[0]]),
                        kind: LayerKind::ConvPool {
                            conv: ConvAttrs::unit([1, 1]),
                            cin: c,
                            cout: c,
                            in_hw: (h, w),
                            conv_out_hw: (h, w),
                            relu: false,
                            pool: Some(PoolAttrs {
                                kernel: [h, w],
                                strides: [h.max(1), w.max(1)],
                                pads: [0, 0],
                                dilations: [1, 1],
                            }),
                            out_hw: (1, 1),
                        },
                    });
                    origin.insert(node.outputs[0].clone(), Some(index));
                    i += 1;
                }
                Op::Add => {
                    let x = &shapes[&node.inputs[0]];
                    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let producers = feed(&origin, &[&node.inputs[0], &node.inputs[1]]);
                    let mut relu = false;
                    let mut out_name = &node.outputs[0];
                    let mut j = i + 1;
                    if let Some(n) = g.nodes.get(j) {
                        if matches!(n.op, Op::Relu) && fusable(out_name, n) {
                            relu = true;
                            out_name = &n.outputs[0];
                            j += 1;
                        }
                    }
                    let index = layers.len();
                    layers.push(FusedLayer {
                        index,
                        producers,
                        kind: LayerKind::Add {
                            channels: c,
                            hw: (h, w),
                            relu,
                        },
                    });
                    origin.insert(out_name.clone(), Some(index));
                    i = j;
                }
                Op::Gemm { .. } => {
                    let x = &shapes[&node.inputs[0]];
                    let out = &shapes[&node.outputs[0]];
                    let producers = feed(&origin, &[&node.inputs[0]]);
                    let mut relu = false;
                    let mut out_name = &node.outputs[0];
                    let mut j = i + 1;
                    if let Some(n) = g.nodes.get(j) {
                        if matches!(n.op, Op::Relu) && fusable(out_name, n) {
                            relu = true;
                            out_name = &n.outputs[0];
                            j += 1;
                        }
                    }
                    let index = layers.len();
                    layers.push(FusedLayer {
                        index,
                        producers,
                        kind: LayerKind::Fc {
                            in_features: x.shape[0],
                            out_features: out.shape[0],
                            relu,
                        },
                    });
                    origin.insert(out_name.clone(), Some(index));
                    i = j;
                }
                Op::Softmax => {
                    has_softmax = true;
                    let o = origin.get(node.inputs[0].as_str()).copied().flatten();
                    origin.insert(node.outputs[0].clone(), o);
                    i += 1;
                }
                Op::Flatten | Op::Relu => {
                    // Flatten is free (address remap); a Relu that was not
                    // fused above is element-wise on the write-back path.
                    // Both alias their producer for downstream feeds.
                    let o = origin.get(node.inputs[0].as_str()).copied().flatten();
                    origin.insert(node.outputs[0].clone(), o);
                    i += 1;
                }
            }
        }
        Ok(ComputationFlow {
            model_name: g.name.clone(),
            input_shape: g.input.shape.clone(),
            layers,
            has_softmax,
        })
    }

    /// Total operation count in GOp (MAC = 2 ops, matching the paper).
    pub fn gops(&self) -> f64 {
        2.0 * self.layers.iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
    }

    pub fn conv_rounds(&self) -> usize {
        self.layers.iter().filter(|l| l.is_conv()).count()
    }

    pub fn fc_rounds(&self) -> usize {
        self.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count()
    }

    /// Whether the flow is a chain-era linear pipeline: every round's
    /// feed wiring is `[index - 1]` and no branch-family round kinds
    /// (Add merges, depthwise convs, grouped convs) appear. Linear flows
    /// take the exact code paths — and produce the exact bytes — of the
    /// pre-DAG extractor.
    pub fn is_linear_chain(&self) -> bool {
        self.layers.iter().all(|l| l.linear_producers() && l.kind_tag() == 0)
    }

    /// Reduction dims of every conv round except the first (the input
    /// round is zero-padded by the host, PipeCNN-style) — the `N_i`
    /// divisor constraint of paper §4.2. Depthwise rounds are exempt:
    /// their k² reduction admits no power-of-two divisor, so they pad
    /// via `div_ceil` like FC rounds.
    pub fn ni_constraint_dims(&self) -> Vec<usize> {
        let first_conv = self.layers.iter().position(|l| l.is_conv());
        self.layers
            .iter()
            .enumerate()
            .filter(|(i, l)| l.is_conv() && Some(*i) != first_conv && !l.is_depthwise())
            .map(|(_, l)| l.reduction_dim())
            .collect()
    }

    /// Output-feature counts of every conv round — the `N_l` divisor
    /// constraint ("N_l should be a divisor of the number of features for
    /// all layers to avoid idle lanes").
    pub fn nl_constraint_dims(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| l.is_conv())
            .map(|l| l.out_features())
            .collect()
    }

    /// Largest activation (elements) crossing a round boundary — sizes the
    /// double-buffered on-chip feature buffers.
    pub fn max_round_activation(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| [l.input_elems(), l.output_elems()])
            .max()
            .unwrap_or(0)
    }

    /// Largest per-round weight tensor (elements) — weight buffer sizing.
    pub fn max_round_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems()).max().unwrap_or(0)
    }

    /// Total weights across rounds.
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems()).sum()
    }

    /// Stable structural fingerprint (FNV-1a over the layer census) —
    /// the model component of the [`crate::dse::eval`] cache key. Two
    /// flows with the same name, input shape and per-round dimensions
    /// hash identically; any structural difference perturbs it. For
    /// chain-era rounds (dense conv, FC, linear feed wiring) the fold is
    /// word-for-word the pre-DAG fingerprint, so existing cache entries
    /// stay valid; branch-family rounds fold an extension record (kind
    /// tag + producer indices) after their census words.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::hash::{fold_bytes, fold_u64, FNV_OFFSET};
        let mut h = fold_bytes(FNV_OFFSET, self.model_name.as_bytes());
        h = fold_u64(h, self.input_shape.len() as u64);
        for &d in &self.input_shape {
            h = fold_u64(h, d as u64);
        }
        for l in &self.layers {
            for word in [
                l.is_conv() as u64,
                l.reduction_dim() as u64,
                l.out_features() as u64,
                l.out_pixels() as u64,
                l.input_elems() as u64,
                l.output_elems() as u64,
                l.macs(),
            ] {
                h = fold_u64(h, word);
            }
            let tag = l.kind_tag();
            if tag != 0 || !l.linear_producers() {
                // branch-extension record: a marker no census word can
                // collide with cheaply, then the structural facts
                h = fold_u64(h, 0xDA6_0F_B0A6C4);
                h = fold_u64(h, tag);
                h = fold_u64(h, l.producers.len() as u64);
                for &p in &l.producers {
                    h = fold_u64(h, p as u64);
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::zoo;

    #[test]
    fn alexnet_fuses_to_5_plus_3_rounds() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.conv_rounds(), 5);
        assert_eq!(flow.fc_rounds(), 3);
        // paper-implied totals
        assert!((flow.gops() - 1.43).abs() < 0.1, "gops={}", flow.gops());
    }

    #[test]
    fn vgg16_fuses_to_13_plus_3_rounds() {
        let g = zoo::build("vgg16", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.conv_rounds(), 13);
        assert_eq!(flow.fc_rounds(), 3);
        assert!((flow.gops() - 30.9).abs() < 0.5);
    }

    #[test]
    fn alexnet_divisor_constraints_admit_paper_options() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        // (16, 32) must be admissible: 16 divides every post-input
        // reduction dim, 32 divides every conv output-feature count
        for d in flow.ni_constraint_dims() {
            assert_eq!(d % 16, 0, "N_i=16 must divide {d}");
        }
        for d in flow.nl_constraint_dims() {
            assert_eq!(d % 32, 0, "N_l=32 must divide {d}");
        }
    }

    #[test]
    fn first_conv_round_shapes() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        match &flow.layers[0].kind {
            LayerKind::ConvPool {
                cin,
                cout,
                conv_out_hw,
                out_hw,
                pool,
                relu,
                ..
            } => {
                assert_eq!((*cin, *cout), (3, 64));
                assert_eq!(*conv_out_hw, (55, 55));
                assert_eq!(*out_hw, (27, 27));
                assert!(pool.is_some() && *relu);
            }
            _ => panic!("expected conv round"),
        }
    }

    #[test]
    fn macs_are_positive_and_flow_total() {
        for name in ["tiny", "lenet5", "alexnet", "vgg16"] {
            let g = zoo::build(name, false).unwrap();
            let flow = ComputationFlow::extract(&g).unwrap();
            assert!(!flow.layers.is_empty());
            assert!(flow.layers.iter().all(|l| l.macs() > 0));
            assert!(flow.has_softmax);
        }
    }

    #[test]
    fn linear_chains_carry_linear_producers() {
        for name in ["tiny", "lenet5", "alexnet", "vgg16"] {
            let g = zoo::build(name, false).unwrap();
            let flow = ComputationFlow::extract(&g).unwrap();
            assert!(flow.is_linear_chain(), "{name}");
            for (i, l) in flow.layers.iter().enumerate() {
                assert!(l.linear_producers(), "{name} L{}", i + 1);
                if i == 0 {
                    assert!(l.producers.is_empty());
                } else {
                    assert_eq!(l.producers, vec![i - 1]);
                }
                assert!(l.has_weights());
            }
        }
    }

    #[test]
    fn linear_fingerprint_matches_the_chain_era_fold() {
        // the exact 7-word-per-round fold the pre-DAG extractor used —
        // linear flows must keep producing its bytes so cache keys and
        // goldens carry over unchanged
        use crate::util::hash::{fold_bytes, fold_u64, FNV_OFFSET};
        for name in ["tiny", "lenet5", "alexnet", "vgg16"] {
            let flow = ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap();
            let mut h = fold_bytes(FNV_OFFSET, flow.model_name.as_bytes());
            h = fold_u64(h, flow.input_shape.len() as u64);
            for &d in &flow.input_shape {
                h = fold_u64(h, d as u64);
            }
            for l in &flow.layers {
                for word in [
                    l.is_conv() as u64,
                    l.reduction_dim() as u64,
                    l.out_features() as u64,
                    l.out_pixels() as u64,
                    l.input_elems() as u64,
                    l.output_elems() as u64,
                    l.macs(),
                ] {
                    h = fold_u64(h, word);
                }
            }
            assert_eq!(flow.fingerprint(), h, "{name}: linear fingerprint drifted");
        }
    }

    #[test]
    fn resnet18_extracts_a_residual_dag() {
        let g = zoo::build("resnet18", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        assert!(!flow.is_linear_chain());
        let adds: Vec<&FusedLayer> = flow
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Add { .. }))
            .collect();
        assert_eq!(adds.len(), 8, "two basic blocks per stage, four stages");
        for add in &adds {
            assert_eq!(add.producers.len(), 2, "{}", add.label());
            assert!(add.producers.iter().all(|&p| p < add.index));
            assert_eq!(add.reduction_dim(), 1);
            assert!(!add.has_weights());
            assert_eq!(add.input_elems(), 2 * add.output_elems());
            match &add.kind {
                LayerKind::Add { relu, .. } => assert!(relu, "block Adds fuse their Relu"),
                _ => unreachable!(),
            }
        }
        // the pre-Add conv of each block must NOT have fused its
        // (post-Add) relu, and the skip producer differs from the linear
        // predecessor on downsample blocks
        assert!(flow.layers.iter().any(|l| !l.linear_producers()));
        // (16, 32) style options stay admissible: every constraint dim
        // is a multiple of 16/32 respectively... the stages are 64-wide
        for d in flow.ni_constraint_dims() {
            assert_eq!(d % 16, 0, "N_i=16 must divide {d}");
        }
        for d in flow.nl_constraint_dims() {
            assert_eq!(d % 32, 0, "N_l=32 must divide {d}");
        }
    }

    #[test]
    fn mobilenetv1_extracts_depthwise_rounds() {
        let g = zoo::build("mobilenetv1", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        let dw: Vec<&FusedLayer> = flow.layers.iter().filter(|l| l.is_depthwise()).collect();
        assert_eq!(dw.len(), 13, "13 separable blocks");
        for l in &dw {
            assert_eq!(l.reduction_dim(), 9, "depthwise reduces over k² alone");
            assert!(l.is_conv());
            assert!(l.has_weights());
            match &l.kind {
                LayerKind::DepthwiseConvPool { channels, conv, .. } => {
                    assert_eq!(l.weight_elems(), channels * 9 + channels);
                    assert_eq!(conv.groups, *channels);
                }
                _ => unreachable!(),
            }
        }
        // depthwise k² = 9 never lands in the ni constraints
        assert!(flow.ni_constraint_dims().iter().all(|&d| d != 9));
        // separable stacks stay a linear pipeline (no Adds), just not
        // chain-era kinds
        assert!(!flow.is_linear_chain());
        assert!(flow.layers.iter().all(|l| l.linear_producers()));
    }

    #[test]
    fn branch_kinds_perturb_the_fingerprint() {
        let res = ComputationFlow::extract(&zoo::build("resnet18", false).unwrap()).unwrap();
        let mobile =
            ComputationFlow::extract(&zoo::build("mobilenetv1", false).unwrap()).unwrap();
        let alex = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
        let prints = [res.fingerprint(), mobile.fingerprint(), alex.fingerprint()];
        assert_eq!(
            prints.iter().collect::<std::collections::HashSet<_>>().len(),
            3,
            "fingerprints must be distinct"
        );
        // and rewiring a producer changes the bytes even when the census
        // words are identical
        let mut rewired = res.clone();
        if let Some(add) = rewired
            .layers
            .iter_mut()
            .find(|l| matches!(l.kind, LayerKind::Add { .. }))
        {
            add.producers.swap(0, 1);
        }
        assert_ne!(rewired.fingerprint(), res.fingerprint());
    }
}
