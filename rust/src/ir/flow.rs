//! Computation-flow extraction (paper §4.1 / §5).
//!
//! The pipelined kernel architecture executes the network as a sequence
//! of *rounds*: each round is one pass of {mem_read -> conv lanes ->
//! pool -> mem_write}. A Conv followed by Relu and/or MaxPool fuses into
//! one round (pool configured as pass-through when absent); a Gemm runs
//! on the same lane array with the pool stage passing through
//! (paper §3.2.3 / §5). AlexNet therefore becomes 5 fused conv/pool
//! rounds + 3 FC rounds — exactly the 8 bars of the paper's Fig. 6.

use super::graph::Graph;
use super::ops::{ConvAttrs, Op, PoolAttrs};
use super::shape::{infer_shapes, ShapeError};

/// One fused pipeline round.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    ConvPool {
        conv: ConvAttrs,
        cin: usize,
        cout: usize,
        in_hw: (usize, usize),
        conv_out_hw: (usize, usize),
        relu: bool,
        pool: Option<PoolAttrs>,
        /// Spatial size after the (optional) pool stage.
        out_hw: (usize, usize),
    },
    Fc {
        in_features: usize,
        out_features: usize,
        relu: bool,
    },
}

/// A fused layer with its cost census.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedLayer {
    pub index: usize,
    pub kind: LayerKind,
}

impl FusedLayer {
    /// Multiply-accumulates in this round (the conv/FC dominates; pool
    /// comparisons are not MACs).
    pub fn macs(&self) -> u64 {
        match &self.kind {
            LayerKind::ConvPool {
                conv,
                cin,
                cout,
                conv_out_hw,
                ..
            } => {
                (conv_out_hw.0 * conv_out_hw.1 * cout * cin * conv.kernel[0] * conv.kernel[1])
                    as u64
            }
            LayerKind::Fc {
                in_features,
                out_features,
                ..
            } => (*in_features * *out_features) as u64,
        }
    }

    /// Reduction-dimension length fed to the lane array (Cin*KH*KW for
    /// conv rounds, K for FC rounds) — the axis the `N_i` vectors tile.
    pub fn reduction_dim(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool {
                conv, cin, ..
            } => cin * conv.kernel[0] * conv.kernel[1],
            LayerKind::Fc { in_features, .. } => *in_features,
        }
    }

    /// Output features produced by the lane array (`N_l` tiles this axis).
    pub fn out_features(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cout, .. } => *cout,
            LayerKind::Fc { out_features, .. } => *out_features,
        }
    }

    /// Output "pixels" per feature (1 for FC rounds).
    pub fn out_pixels(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { conv_out_hw, .. } => conv_out_hw.0 * conv_out_hw.1,
            LayerKind::Fc { .. } => 1,
        }
    }

    /// Weight elements this round streams from memory.
    pub fn weight_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool {
                conv, cin, cout, ..
            } => cout * cin * conv.kernel[0] * conv.kernel[1] + cout,
            LayerKind::Fc {
                in_features,
                out_features,
                ..
            } => in_features * out_features + out_features,
        }
    }

    /// Input activation elements this round reads.
    pub fn input_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cin, in_hw, .. } => cin * in_hw.0 * in_hw.1,
            LayerKind::Fc { in_features, .. } => *in_features,
        }
    }

    /// Output activation elements this round writes (after pool).
    pub fn output_elems(&self) -> usize {
        match &self.kind {
            LayerKind::ConvPool { cout, out_hw, .. } => cout * out_hw.0 * out_hw.1,
            LayerKind::Fc { out_features, .. } => *out_features,
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self.kind, LayerKind::ConvPool { .. })
    }

    /// Human-readable round label ("L2 conv+pool", "L6 fc") — shared by
    /// the latency breakdown, the stepped census and the specialization
    /// table so their rows align textually.
    pub fn label(&self) -> String {
        match &self.kind {
            LayerKind::ConvPool { pool, .. } => {
                if pool.is_some() {
                    format!("L{} conv+pool", self.index + 1)
                } else {
                    format!("L{} conv", self.index + 1)
                }
            }
            LayerKind::Fc { .. } => format!("L{} fc", self.index + 1),
        }
    }
}

/// The extracted computation flow of a model.
#[derive(Debug, Clone)]
pub struct ComputationFlow {
    pub model_name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<FusedLayer>,
    pub has_softmax: bool,
}

impl ComputationFlow {
    /// Extract from a validated, shape-inferred graph.
    pub fn extract(g: &Graph) -> Result<ComputationFlow, ShapeError> {
        g.validate().map_err(ShapeError)?;
        let shapes = infer_shapes(g)?;
        let mut layers = Vec::new();
        let mut has_softmax = false;
        let mut i = 0;
        while i < g.nodes.len() {
            let node = &g.nodes[i];
            match &node.op {
                Op::Conv(attrs) => {
                    let x = &shapes[&node.inputs[0]];
                    let (cin, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let conv_out = &shapes[&node.outputs[0]];
                    let cout = conv_out.shape[0];
                    let conv_out_hw = (conv_out.shape[1], conv_out.shape[2]);
                    let mut relu = false;
                    let mut pool = None;
                    let mut out_hw = conv_out_hw;
                    let mut j = i + 1;
                    if let Some(n) = g.nodes.get(j) {
                        if matches!(n.op, Op::Relu) {
                            relu = true;
                            j += 1;
                        }
                    }
                    if let Some(n) = g.nodes.get(j) {
                        if let Op::MaxPool(pattrs) = &n.op {
                            pool = Some(*pattrs);
                            let po = &shapes[&n.outputs[0]];
                            out_hw = (po.shape[1], po.shape[2]);
                            j += 1;
                        }
                    }
                    layers.push(FusedLayer {
                        index: layers.len(),
                        kind: LayerKind::ConvPool {
                            conv: *attrs,
                            cin,
                            cout,
                            in_hw: (h, w),
                            conv_out_hw,
                            relu,
                            pool,
                            out_hw,
                        },
                    });
                    i = j;
                }
                Op::MaxPool(pattrs) => {
                    // standalone pool (no preceding conv): model it as a
                    // pass-through conv round with a 1x1 identity — rare,
                    // but keeps the flow total.
                    let x = &shapes[&node.inputs[0]];
                    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
                    let po = &shapes[&node.outputs[0]];
                    layers.push(FusedLayer {
                        index: layers.len(),
                        kind: LayerKind::ConvPool {
                            conv: ConvAttrs::unit([1, 1]),
                            cin: c,
                            cout: c,
                            in_hw: (h, w),
                            conv_out_hw: (h, w),
                            relu: false,
                            pool: Some(*pattrs),
                            out_hw: (po.shape[1], po.shape[2]),
                        },
                    });
                    i += 1;
                }
                Op::Gemm { .. } => {
                    let x = &shapes[&node.inputs[0]];
                    let out = &shapes[&node.outputs[0]];
                    let mut relu = false;
                    let mut j = i + 1;
                    if let Some(n) = g.nodes.get(j) {
                        if matches!(n.op, Op::Relu) {
                            relu = true;
                            j += 1;
                        }
                    }
                    layers.push(FusedLayer {
                        index: layers.len(),
                        kind: LayerKind::Fc {
                            in_features: x.shape[0],
                            out_features: out.shape[0],
                            relu,
                        },
                    });
                    i = j;
                }
                Op::Softmax => {
                    has_softmax = true;
                    i += 1;
                }
                Op::Flatten | Op::Relu => {
                    // Flatten is free (address remap); a Relu that was not
                    // fused above is element-wise on the write-back path.
                    i += 1;
                }
            }
        }
        Ok(ComputationFlow {
            model_name: g.name.clone(),
            input_shape: g.input.shape.clone(),
            layers,
            has_softmax,
        })
    }

    /// Total operation count in GOp (MAC = 2 ops, matching the paper).
    pub fn gops(&self) -> f64 {
        2.0 * self.layers.iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
    }

    pub fn conv_rounds(&self) -> usize {
        self.layers.iter().filter(|l| l.is_conv()).count()
    }

    pub fn fc_rounds(&self) -> usize {
        self.layers.len() - self.conv_rounds()
    }

    /// Reduction dims of every conv round except the first (the input
    /// round is zero-padded by the host, PipeCNN-style) — the `N_i`
    /// divisor constraint of paper §4.2.
    pub fn ni_constraint_dims(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| l.is_conv())
            .skip(1)
            .map(|l| l.reduction_dim())
            .collect()
    }

    /// Output-feature counts of every conv round — the `N_l` divisor
    /// constraint ("N_l should be a divisor of the number of features for
    /// all layers to avoid idle lanes").
    pub fn nl_constraint_dims(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| l.is_conv())
            .map(|l| l.out_features())
            .collect()
    }

    /// Largest activation (elements) crossing a round boundary — sizes the
    /// double-buffered on-chip feature buffers.
    pub fn max_round_activation(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| [l.input_elems(), l.output_elems()])
            .max()
            .unwrap_or(0)
    }

    /// Largest per-round weight tensor (elements) — weight buffer sizing.
    pub fn max_round_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems()).max().unwrap_or(0)
    }

    /// Total weights across rounds.
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems()).sum()
    }

    /// Stable structural fingerprint (FNV-1a over the layer census) —
    /// the model component of the [`crate::dse::eval`] cache key. Two
    /// flows with the same name, input shape and per-round dimensions
    /// hash identically; any structural difference perturbs it.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::hash::{fold_bytes, fold_u64, FNV_OFFSET};
        let mut h = fold_bytes(FNV_OFFSET, self.model_name.as_bytes());
        h = fold_u64(h, self.input_shape.len() as u64);
        for &d in &self.input_shape {
            h = fold_u64(h, d as u64);
        }
        for l in &self.layers {
            for word in [
                l.is_conv() as u64,
                l.reduction_dim() as u64,
                l.out_features() as u64,
                l.out_pixels() as u64,
                l.input_elems() as u64,
                l.output_elems() as u64,
                l.macs(),
            ] {
                h = fold_u64(h, word);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::zoo;

    #[test]
    fn alexnet_fuses_to_5_plus_3_rounds() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.conv_rounds(), 5);
        assert_eq!(flow.fc_rounds(), 3);
        // paper-implied totals
        assert!((flow.gops() - 1.43).abs() < 0.1, "gops={}", flow.gops());
    }

    #[test]
    fn vgg16_fuses_to_13_plus_3_rounds() {
        let g = zoo::build("vgg16", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.conv_rounds(), 13);
        assert_eq!(flow.fc_rounds(), 3);
        assert!((flow.gops() - 30.9).abs() < 0.5);
    }

    #[test]
    fn alexnet_divisor_constraints_admit_paper_options() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        // (16, 32) must be admissible: 16 divides every post-input
        // reduction dim, 32 divides every conv output-feature count
        for d in flow.ni_constraint_dims() {
            assert_eq!(d % 16, 0, "N_i=16 must divide {d}");
        }
        for d in flow.nl_constraint_dims() {
            assert_eq!(d % 32, 0, "N_l=32 must divide {d}");
        }
    }

    #[test]
    fn first_conv_round_shapes() {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        match &flow.layers[0].kind {
            LayerKind::ConvPool {
                cin,
                cout,
                conv_out_hw,
                out_hw,
                pool,
                relu,
                ..
            } => {
                assert_eq!((*cin, *cout), (3, 64));
                assert_eq!(*conv_out_hw, (55, 55));
                assert_eq!(*out_hw, (27, 27));
                assert!(pool.is_some() && *relu);
            }
            _ => panic!("expected conv round"),
        }
    }

    #[test]
    fn macs_are_positive_and_flow_total() {
        for name in ["tiny", "lenet5", "alexnet", "vgg16"] {
            let g = zoo::build(name, false).unwrap();
            let flow = ComputationFlow::extract(&g).unwrap();
            assert!(!flow.layers.is_empty());
            assert!(flow.layers.iter().all(|l| l.macs() > 0));
            assert!(flow.has_softmax);
        }
    }
}
