//! FPGA device database.
//!
//! Resource inventories come straight from the paper's Table 2
//! ("Resources Available") for the three evaluation boards; the extra
//! fields (block size, register ratio, DSP int8-MAC capability, base
//! clock) are family-level datasheet facts used by the analytical model.

/// FPGA family — sets the per-family constants of the resource model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    CycloneV,
    Arria10,
    StratixV,
}

/// A concrete FPGA device (board-level view).
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub family: Family,
    /// Adaptive logic modules.
    pub alms: u64,
    /// DSP blocks.
    pub dsps: u64,
    /// On-chip RAM blocks (M10K for Cyclone/Stratix V, M20K for Arria 10).
    pub ram_blocks: u64,
    /// Total on-chip memory bits.
    pub mem_bits: u64,
    /// Bits per RAM block.
    pub ram_block_bits: u64,
    /// Registers per ALM (family architecture fact).
    pub regs_per_alm: u64,
    /// int8 MACs one DSP block can perform per cycle.
    pub macs_per_dsp: u64,
    /// Achievable kernel clock for this family under low congestion (MHz).
    pub base_clock_mhz: f64,
    /// Effective global-memory bandwidth the OpenCL memory kernels see
    /// (GB/s): one DDR3 bank on the Cyclone V SoC, one effective DDR4
    /// bank on the Nallatech 510T Arria 10 board.
    pub ddr_gbytes_per_s: f64,
    /// Pipeline duty factor of the synthesized kernels (fraction of
    /// cycles the lane array does useful work) — calibrated against the
    /// paper's Table 1 AlexNet anchors; see sim::engine.
    pub duty_factor: f64,
}

impl Device {
    pub fn registers(&self) -> u64 {
        self.alms * self.regs_per_alm
    }

    /// Stable fingerprint of the full inventory — the device component
    /// of the [`crate::dse::eval`] cache key. Keyed on every field (not
    /// just the name) so a hand-edited `Device` never aliases a stock
    /// one in the estimator memo.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::hash::{fold_bytes, fold_u64, FNV_OFFSET};
        let mut h = fold_bytes(FNV_OFFSET, self.name.as_bytes());
        let family = match self.family {
            Family::CycloneV => 0u64,
            Family::Arria10 => 1,
            Family::StratixV => 2,
        };
        for word in [
            family,
            self.alms,
            self.dsps,
            self.ram_blocks,
            self.mem_bits,
            self.ram_block_bits,
            self.regs_per_alm,
            self.macs_per_dsp,
            self.base_clock_mhz.to_bits(),
            self.ddr_gbytes_per_s.to_bits(),
            self.duty_factor.to_bits(),
        ] {
            h = fold_u64(h, word);
        }
        h
    }
}

/// The boards of the paper's Tables 1-2.
pub const CYCLONE_V_5CSEMA4: Device = Device {
    name: "Cyclone V SoC 5CSEMA4",
    family: Family::CycloneV,
    alms: 15_000,
    dsps: 83,
    ram_blocks: 321,
    mem_bits: 3_200_000,
    ram_block_bits: 10_240,
    regs_per_alm: 4,
    macs_per_dsp: 1,
    base_clock_mhz: 152.0,
    ddr_gbytes_per_s: 3.2,
    duty_factor: 0.655,
};

pub const CYCLONE_V_5CSEMA5: Device = Device {
    name: "Cyclone V SoC 5CSEMA5",
    family: Family::CycloneV,
    alms: 32_000,
    dsps: 87,
    ram_blocks: 397,
    mem_bits: 4_000_000,
    ram_block_bits: 10_240,
    regs_per_alm: 4,
    macs_per_dsp: 1,
    base_clock_mhz: 152.0,
    ddr_gbytes_per_s: 3.2,
    duty_factor: 0.655,
};

pub const ARRIA_10_GX1150: Device = Device {
    name: "Arria 10 GX 1150",
    family: Family::Arria10,
    alms: 427_000,
    dsps: 1516,
    ram_blocks: 2713,
    mem_bits: 55_500_000,
    ram_block_bits: 20_480,
    regs_per_alm: 4,
    macs_per_dsp: 2,
    base_clock_mhz: 199.0,
    ddr_gbytes_per_s: 8.0,
    duty_factor: 0.78,
};

/// Stratix V appears only as a baseline platform in Tables 3-4.
pub const STRATIX_V_GXD8: Device = Device {
    name: "Stratix V GX-D8",
    family: Family::StratixV,
    alms: 262_400,
    dsps: 1963,
    ram_blocks: 2567,
    mem_bits: 52_000_000,
    ram_block_bits: 20_480,
    regs_per_alm: 4,
    macs_per_dsp: 2,
    base_clock_mhz: 180.0,
    ddr_gbytes_per_s: 6.4,
    duty_factor: 0.7,
};

/// All paper evaluation devices.
pub fn all() -> Vec<&'static Device> {
    vec![
        &CYCLONE_V_5CSEMA4,
        &CYCLONE_V_5CSEMA5,
        &ARRIA_10_GX1150,
        &STRATIX_V_GXD8,
    ]
}

/// Lookup by (case-insensitive) substring, e.g. "arria10", "5csema5".
pub fn find(name: &str) -> Option<&'static Device> {
    let needle: String = name
        .to_ascii_lowercase()
        .chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .collect();
    all().into_iter().find(|d| {
        let hay: String = d
            .name
            .to_ascii_lowercase()
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect();
        hay.contains(&needle)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_inventories() {
        assert_eq!(CYCLONE_V_5CSEMA4.alms, 15_000);
        assert_eq!(CYCLONE_V_5CSEMA5.ram_blocks, 397);
        assert_eq!(ARRIA_10_GX1150.dsps, 1516);
        assert_eq!(ARRIA_10_GX1150.mem_bits, 55_500_000);
    }

    #[test]
    fn find_by_fuzzy_name() {
        assert_eq!(find("Arria 10").unwrap().name, ARRIA_10_GX1150.name);
        assert_eq!(find("5csema5").unwrap().name, CYCLONE_V_5CSEMA5.name);
        assert_eq!(find("SoC 5CSEMA4").unwrap().name, CYCLONE_V_5CSEMA4.name);
        assert!(find("virtex7").is_none());
    }
}
