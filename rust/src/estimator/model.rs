//! Analytical FPGA resource model — the stand-in for the Intel OpenCL
//! compiler's estimation stage (DESIGN.md §2, §8).
//!
//! The DSE loop only consumes four utilization percentages
//! (P_lut, P_dsp, P_mem, P_reg); any monotone model with the paper's
//! feasibility frontier exercises the identical DSE code path.  The
//! constants below are calibrated against the paper's published anchor
//! points (Table 1 + Table 2):
//!
//!   Cyclone V 5CSEMA5 @ (8,8), AlexNet : 26K ALM, 72 DSP, 397 RAM
//!                                        blocks, ~2 Mbit, fmax 131 MHz
//!   Arria 10 GX1150 @ (16,32), AlexNet : 129K ALM, 300 DSP, ~40% RAM,
//!                                        fmax 199 MHz
//!   Cyclone V 5CSEMA4 (15K ALM)        : infeasible at every option
//!
//! Derivations are commented next to each constant.

use crate::ir::ComputationFlow;

use super::device::{Device, Family};

/// Per-family model constants.
#[derive(Debug, Clone, Copy)]
pub struct FamilyConsts {
    /// ALMs consumed by the fixed control plane: host interface, DDR
    /// controller, kernel schedulers. Calibrated so 5CSEMA4 (15K) cannot
    /// fit even the minimum option while 5CSEMA5 lands on 26K at (8,8).
    pub base_ctrl_alms: f64,
    /// DSPs consumed outside the lane array (address generation in the
    /// memory read/write kernels).
    pub base_dsps: f64,
    /// RAM blocks consumed by the control plane / host FIFOs.
    pub base_ram_blocks: f64,
    /// Fraction of device memory bits the synthesizer budgets for the
    /// double-buffered feature buffers (small parts reuse aggressively;
    /// large parts cap the budget to keep routing feasible). Calibrated:
    /// CycloneV 0.25 reproduces the 397-block / ~2 Mbit AlexNet anchor,
    /// Arria 10 0.10 reproduces ~40% RAM for AlexNet and the paper's
    /// "VGG-16 uses 8% more block RAM" delta.
    pub feat_budget_frac: f64,
    /// Same for the weight-slice buffers.
    pub weight_budget_frac: f64,
    /// Synthesis wall-time per K ALMs used (minutes) — Table 2 anchors:
    /// 46 min / 26K (CycloneV), 8.5 h / 129K (Arria 10).
    pub synth_min_per_kalm: f64,
}

impl Family {
    pub fn consts(self) -> FamilyConsts {
        match self {
            Family::CycloneV => FamilyConsts {
                base_ctrl_alms: 20_000.0,
                base_dsps: 8.0,
                base_ram_blocks: 80.0,
                feat_budget_frac: 0.25,
                weight_budget_frac: 0.30,
                synth_min_per_kalm: 1.77, // 46 min / 26 K ALMs
            },
            Family::Arria10 => FamilyConsts {
                base_ctrl_alms: 90_000.0,
                base_dsps: 44.0,
                base_ram_blocks: 320.0,
                feat_budget_frac: 0.10,
                weight_budget_frac: 0.10,
                synth_min_per_kalm: 3.95, // 510 min / 129 K ALMs
            },
            Family::StratixV => FamilyConsts {
                base_ctrl_alms: 60_000.0,
                base_dsps: 24.0,
                base_ram_blocks: 220.0,
                feat_budget_frac: 0.10,
                weight_budget_frac: 0.10,
                synth_min_per_kalm: 3.0,
            },
        }
    }
}

/// ALMs per computation lane (lane control, accumulator mux, RELU unit):
/// shared across families.  Solved with C_VEC from the two ALM anchors.
const C_LANE_ALMS: f64 = 270.0;
/// ALMs per (N_i x N_l) MAC slot (vector routing + partial-sum wiring).
const C_VEC_ALMS: f64 = 60.0;
/// Registers consumed per used ALM (pipeline registers dominate).
const REGS_PER_USED_ALM: f64 = 2.2;
/// FIFO pipe depth (elements) between pipeline stages — PipeCNN default.
pub const PIPE_DEPTH: usize = 512;

/// Resource estimate for one (N_i, N_l) option on one device.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceEstimate {
    pub ni: usize,
    pub nl: usize,
    pub alms: f64,
    pub dsps: f64,
    pub ram_blocks: f64,
    pub mem_bits: f64,
    pub registers: f64,
    /// Utilization percentages (0-100), the estimator feedback of §4.3.
    pub p_lut: f64,
    pub p_dsp: f64,
    pub p_mem: f64,
    pub p_reg: f64,
    pub fmax_mhz: f64,
}

impl ResourceEstimate {
    /// Average usage factor, paper eq. (5).
    pub fn f_avg(&self) -> f64 {
        (self.p_lut + self.p_dsp + self.p_mem + self.p_reg) / 4.0
    }

    /// Feasible under a threshold vector (paper Algorithm 1's
    /// componentwise comparison).
    pub fn fits(&self, th: &Thresholds) -> bool {
        self.p_lut < th.lut && self.p_dsp < th.dsp && self.p_mem < th.mem && self.p_reg < th.reg
    }
}

/// T_th of Algorithm 1: per-quota maximum tolerated utilization (%).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Thresholds {
    pub lut: f64,
    pub dsp: f64,
    pub mem: f64,
    pub reg: f64,
}

impl Default for Thresholds {
    fn default() -> Self {
        // 100% on every quota: the paper's runs drive RAM to 100% on the
        // Cyclone V, so the fitter must admit full utilization.
        Thresholds {
            lut: 101.0,
            dsp: 101.0,
            mem: 101.0,
            reg: 101.0,
        }
    }
}

/// Estimate resources for `flow` at option (ni, nl) on `device`.
///
/// This is the "first stage of the synthesis tool" of paper §4.3 — it
/// must be cheap (the DSE calls it in a loop) and monotone in both knobs.
pub fn estimate(
    flow: &ComputationFlow,
    device: &Device,
    ni: usize,
    nl: usize,
) -> ResourceEstimate {
    let fam = device.family.consts();

    // --- DSP: the lane array performs ni*nl int8 MACs per cycle --------
    let lane_macs = (ni * nl) as f64;
    let dsps = (lane_macs / device.macs_per_dsp as f64).ceil() + fam.base_dsps;

    // --- ALM ------------------------------------------------------------
    let alms = fam.base_ctrl_alms + C_LANE_ALMS * nl as f64 + C_VEC_ALMS * lane_macs;

    // --- Memory ----------------------------------------------------------
    // Double-buffered output feature buffers (int8 codes): the written
    // round output stays on chip while the next round drains it, capped
    // by the family's buffer budget (bigger rounds spill to DDR tiles —
    // the simulator charges the extra traffic).
    let max_out = flow
        .layers
        .iter()
        .map(|l| l.output_elems())
        .max()
        .unwrap_or(0) as f64;
    let feat_bits = (2.0 * max_out * 8.0).min(fam.feat_budget_frac * device.mem_bits as f64);
    // Weight slice buffer: weights for nl output features across the
    // longest reduction dim, double-buffered while the next slice loads;
    // same budget cap.
    let max_red = flow
        .layers
        .iter()
        .map(|l| l.reduction_dim())
        .max()
        .unwrap_or(0) as f64;
    let w_bits =
        (2.0 * max_red * nl as f64 * 8.0).min(fam.weight_budget_frac * device.mem_bits as f64);
    let mem_bits = feat_bits + w_bits;
    // Block count: buffers are banked per lane / per vector so each bank
    // rounds up to whole physical blocks (granularity loss is real and
    // why the 5CSEMA5 exhausts blocks before bits), plus the three FIFO
    // pipe sets of the PipeCNN topology (rd->conv, conv->pool, pool->wr).
    let bb = device.ram_block_bits as f64;
    let feat_blocks = nl as f64 * (feat_bits / nl as f64 / bb).ceil();
    let w_blocks = ni as f64 * (w_bits / ni as f64 / bb).ceil();
    let pipe_blocks = 3.0 * nl as f64 * ((PIPE_DEPTH * ni) as f64 * 8.0 / bb).ceil();
    let ram_blocks = fam.base_ram_blocks + feat_blocks + w_blocks + pipe_blocks;

    // --- Registers --------------------------------------------------------
    let registers = alms * REGS_PER_USED_ALM;

    // --- Percentages --------------------------------------------------------
    let p_lut = 100.0 * alms / device.alms as f64;
    let p_dsp = 100.0 * dsps / device.dsps as f64;
    let p_mem = 100.0 * ram_blocks / device.ram_blocks as f64;
    let p_reg = 100.0 * registers / device.registers() as f64;

    // --- fmax: congestion derating above ~40% average utilization ------
    let f_avg = (p_lut + p_dsp + p_mem + p_reg) / 4.0;
    let derate = 1.0 - 0.30 * ((f_avg / 100.0 - 0.4).max(0.0) / 0.6);
    let fmax_mhz = device.base_clock_mhz * derate;

    ResourceEstimate {
        ni,
        nl,
        alms,
        dsps,
        ram_blocks,
        mem_bits,
        registers,
        p_lut,
        p_dsp,
        p_mem,
        p_reg,
        fmax_mhz,
    }
}

/// Synthesis wall-time model (minutes) for a fitted design — Table 2's
/// "Synthesis time" column (46 min Cyclone V, 8.5 h Arria 10).
pub fn synthesis_minutes(est: &ResourceEstimate, device: &Device) -> f64 {
    device.family.consts().synth_min_per_kalm * est.alms / 1000.0
}

/// Estimator query wall-time model (seconds): the paper's DSE timings
/// imply ~17 s per Intel-compiler estimation query on the Cyclone V and
/// ~20 s on the Arria 10 (Table 2: BF-DSE 3.5 min / 4 min over the
/// 12-option AlexNet grid).
pub fn query_seconds(device: &Device) -> f64 {
    16.0 + 3.5 * device.alms as f64 / 427_000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::ir::ComputationFlow;
    use crate::onnx::zoo;

    fn alexnet_flow() -> ComputationFlow {
        ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap()
    }

    #[test]
    fn cyclone_v_anchor_8_8() {
        let est = estimate(&alexnet_flow(), &CYCLONE_V_5CSEMA5, 8, 8);
        // Table 2: ALM 26K, DSP 72, RAM blocks 397 (100%), ~2 Mbit
        assert!((est.alms - 26_000.0).abs() < 1500.0, "alms={}", est.alms);
        assert!((est.dsps - 72.0).abs() < 1.0, "dsps={}", est.dsps);
        assert!(
            (est.ram_blocks - 397.0).abs() < 40.0,
            "ram={}",
            est.ram_blocks
        );
        assert!(
            (est.mem_bits - 2.0e6).abs() < 0.5e6,
            "mem_bits={}",
            est.mem_bits
        );
        // Table 1: fmax 131 MHz
        assert!((est.fmax_mhz - 131.0).abs() < 8.0, "fmax={}", est.fmax_mhz);
    }

    #[test]
    fn arria10_anchor_16_32() {
        let est = estimate(&alexnet_flow(), &ARRIA_10_GX1150, 16, 32);
        // Table 3: 129K ALMs (30%), 300 DSP (20%); Table 1: RAM ~40%, 199 MHz
        assert!((est.alms - 129_000.0).abs() < 8_000.0, "alms={}", est.alms);
        assert!((est.dsps - 300.0).abs() < 5.0, "dsps={}", est.dsps);
        assert!((est.p_lut - 30.0).abs() < 3.0, "p_lut={}", est.p_lut);
        assert!((est.p_dsp - 20.0).abs() < 1.5, "p_dsp={}", est.p_dsp);
        assert!((est.p_mem - 40.0).abs() < 12.0, "p_mem={}", est.p_mem);
        assert!((est.fmax_mhz - 199.0).abs() < 6.0, "fmax={}", est.fmax_mhz);
    }

    #[test]
    fn small_cyclone_never_fits() {
        // Table 2: 5CSEMA4 "Does not fit" — at every admissible option.
        let flow = alexnet_flow();
        let th = Thresholds::default();
        for ni in [4, 8, 16, 32, 64] {
            for nl in [4, 8, 16, 32, 64] {
                let est = estimate(&flow, &CYCLONE_V_5CSEMA4, ni, nl);
                assert!(!est.fits(&th), "({ni},{nl}) unexpectedly fits");
            }
        }
    }

    #[test]
    fn model_is_monotone_in_both_knobs() {
        let flow = alexnet_flow();
        let mut last = 0.0;
        for nl in [4, 8, 16, 32, 64] {
            let est = estimate(&flow, &ARRIA_10_GX1150, 16, nl);
            assert!(est.alms > last && est.dsps > 0.0);
            last = est.alms;
        }
        let a = estimate(&flow, &ARRIA_10_GX1150, 8, 16);
        let b = estimate(&flow, &ARRIA_10_GX1150, 16, 16);
        assert!(b.f_avg() > a.f_avg());
    }

    #[test]
    fn synthesis_time_anchors() {
        let flow = alexnet_flow();
        let cv = estimate(&flow, &CYCLONE_V_5CSEMA5, 8, 8);
        let t_cv = synthesis_minutes(&cv, &CYCLONE_V_5CSEMA5);
        assert!((t_cv - 46.0).abs() < 6.0, "cv synth {t_cv} min");
        let a10 = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let t_a10 = synthesis_minutes(&a10, &ARRIA_10_GX1150);
        assert!((t_a10 - 510.0).abs() < 40.0, "a10 synth {t_a10} min");
    }

    #[test]
    fn f_avg_is_mean_of_percentages() {
        let est = estimate(&alexnet_flow(), &ARRIA_10_GX1150, 8, 8);
        let mean = (est.p_lut + est.p_dsp + est.p_mem + est.p_reg) / 4.0;
        assert!((est.f_avg() - mean).abs() < 1e-9);
    }
}
