//! Hardware-aware resource/timing estimation (paper §4.3) over a device
//! database — the simulated stand-in for the Intel OpenCL compiler's
//! estimation stage.

pub mod device;
pub mod model;

pub use device::{Device, Family};
pub use model::{estimate, query_seconds, synthesis_minutes, ResourceEstimate, Thresholds};
