//! Post-training quantization application (paper §4.2).
//!
//! "CNN2Gate does not perform quantization itself, however, it can apply
//! a given value that the user provides for a layer. This value can be
//! expressed as an (N, m) pair where fixed-point weights/biases values
//! are represented as N x 2^-m."
//!
//! [`QuantSpec`] carries the user-given per-layer (or global) formats;
//! [`apply`] converts a float [`Graph`]'s initializers to int8 codes and
//! reports per-tensor error statistics, which the emulation mode uses to
//! decide whether the chosen m-values are acceptable before synthesis.

use std::collections::HashMap;

use crate::ir::Graph;
use crate::util::fixed::{quantize_tensor, FixedFormat};

/// Per-layer fixed-point configuration, mirroring the Python DEFAULT_QCFG.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerQuant {
    /// Fractional bits of input activation codes.
    pub m_in: i8,
    /// Fractional bits of weight codes.
    pub m_w: i8,
    /// Fractional bits of output activation codes.
    pub m_out: i8,
}

impl Default for LayerQuant {
    fn default() -> Self {
        // matches python/compile/model.py DEFAULT_QCFG
        LayerQuant {
            m_in: 4,
            m_w: 6,
            m_out: 4,
        }
    }
}

impl LayerQuant {
    /// Accumulator fractional bits (int32 accumulation).
    pub fn m_acc(&self) -> i8 {
        self.m_in + self.m_w
    }
}

/// The user-provided quantization for a model: a global default plus
/// optional per-layer overrides keyed by fused-layer index.
#[derive(Debug, Clone, Default)]
pub struct QuantSpec {
    pub default: LayerQuant,
    pub per_layer: HashMap<usize, LayerQuant>,
}

impl QuantSpec {
    pub fn uniform(q: LayerQuant) -> Self {
        QuantSpec {
            default: q,
            per_layer: HashMap::new(),
        }
    }

    pub fn layer(&self, idx: usize) -> LayerQuant {
        self.per_layer.get(&idx).copied().unwrap_or(self.default)
    }
}

/// Quantized tensor + its error statistics.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub name: String,
    pub codes: Vec<i8>,
    pub m: i8,
    pub max_abs_err: f64,
    pub mean_abs_err: f64,
    /// Fraction of elements that saturated.
    pub sat_ratio: f64,
}

/// Result of applying a QuantSpec to a model's weights.
#[derive(Debug, Clone)]
pub struct QuantReport {
    pub tensors: Vec<QuantizedTensor>,
}

impl QuantReport {
    pub fn worst_sat_ratio(&self) -> f64 {
        self.tensors.iter().map(|t| t.sat_ratio).fold(0.0, f64::max)
    }

    pub fn worst_abs_err(&self) -> f64 {
        self.tensors.iter().map(|t| t.max_abs_err).fold(0.0, f64::max)
    }

    pub fn tensor(&self, name: &str) -> Option<&QuantizedTensor> {
        self.tensors.iter().find(|t| t.name == name)
    }
}

/// Quantize one float tensor to int8 with `m` fractional bits + stats.
pub fn quantize_with_stats(name: &str, data: &[f32], m: i8) -> QuantizedTensor {
    let fmt = FixedFormat::q8(m);
    let codes = quantize_tensor(data, m);
    let mut max_err = 0f64;
    let mut sum_err = 0f64;
    let mut saturated = 0usize;
    for (&x, &c) in data.iter().zip(&codes) {
        let err = (fmt.dequantize(c as i64) - x).abs() as f64;
        max_err = max_err.max(err);
        sum_err += err;
        if c as i64 == fmt.min_code() || c as i64 == fmt.max_code() {
            saturated += 1;
        }
    }
    let n = data.len().max(1) as f64;
    QuantizedTensor {
        name: name.to_string(),
        codes,
        m,
        max_abs_err: max_err,
        mean_abs_err: sum_err / n,
        sat_ratio: saturated as f64 / n,
    }
}

/// Apply the spec to every *weight* initializer of a graph (biases go to
/// the int32 accumulator scale and are kept as widened codes by the
/// runtime; the 8-bit census here covers the tensors the DSP lanes see).
///
/// Weight initializer names follow the zoo/aot convention `l<idx>_w`.
pub fn apply(g: &Graph, spec: &QuantSpec) -> Result<QuantReport, String> {
    if !g.has_weights() {
        return Err(format!(
            "model '{}' has no resident weights to quantize",
            g.name
        ));
    }
    let mut tensors = Vec::new();
    let mut names: Vec<&String> = g.initializers.keys().collect();
    names.sort(); // deterministic report order
    for name in names {
        if !name.ends_with("_w") {
            continue;
        }
        let idx: usize = name
            .trim_start_matches('l')
            .trim_end_matches("_w")
            .parse()
            .unwrap_or(0);
        let q = spec.layer(idx);
        let Some(data) = g.initializers.get(name).and_then(|init| init.data.as_ref()) else {
            return Err(format!("weight initializer '{name}' carries no data"));
        };
        tensors.push(quantize_with_stats(name, data, q.m_w));
    }
    if tensors.is_empty() {
        return Err("no weight tensors found (expected l<idx>_w naming)".into());
    }
    Ok(QuantReport { tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::zoo;
    use crate::testkit::for_all;

    #[test]
    fn apply_reports_every_weight_tensor() {
        let g = zoo::build("lenet5", true).unwrap();
        let report = apply(&g, &QuantSpec::default()).unwrap();
        let expected = g.initializers.keys().filter(|k| k.ends_with("_w")).count();
        assert_eq!(report.tensors.len(), expected);
        assert!(report.worst_abs_err() <= 0.5 * 2f64.powi(-6) + 1e-9 || report.worst_sat_ratio() > 0.0);
    }

    #[test]
    fn apply_requires_weights() {
        let g = zoo::build("alexnet", false).unwrap();
        assert!(apply(&g, &QuantSpec::default()).is_err());
    }

    #[test]
    fn per_layer_override_wins() {
        let mut spec = QuantSpec::default();
        spec.per_layer.insert(
            2,
            LayerQuant {
                m_in: 1,
                m_w: 2,
                m_out: 1,
            },
        );
        assert_eq!(spec.layer(2).m_w, 2);
        assert_eq!(spec.layer(0).m_w, spec.default.m_w);
    }

    #[test]
    fn stats_error_bound_property() {
        for_all("quantize error bounded by half LSB unless saturated", |g| {
            let m = g.int(0, 7) as i8;
            let len = g.usize(1, 256);
            let data = g.tensor(len, 2.0);
            let t = quantize_with_stats("w", &data, m);
            let fmt = FixedFormat::q8(m);
            if t.sat_ratio == 0.0 {
                assert!(t.max_abs_err <= fmt.max_abs_error() + 1e-9);
            }
            assert!(t.mean_abs_err <= t.max_abs_err + 1e-12);
        });
    }

    #[test]
    fn m_acc_is_sum() {
        let q = LayerQuant {
            m_in: 3,
            m_w: 5,
            m_out: 2,
        };
        assert_eq!(q.m_acc(), 8);
    }
}
