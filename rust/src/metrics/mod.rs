//! Throughput / performance-density metrics used across Tables 1, 3, 4.
//!
//! The paper compares designs by latency (ms, batch 1), Performance
//! (GOp/s, counting MAC = 2 ops) and performance density (GOp/s/DSP —
//! §5: "CNN2Gate performance density (GOp/s/DSP) is higher (0.266) when
//! compared to 0.234 for [20]").

/// Achieved throughput in GOp/s for `gops` of work finished in `ms`.
pub fn gops_per_s(gops: f64, ms: f64) -> f64 {
    if ms <= 0.0 {
        return 0.0;
    }
    gops / (ms / 1e3)
}

/// Performance density (GOp/s per DSP block).
pub fn gops_per_dsp(gops_per_s: f64, dsps: f64) -> f64 {
    if dsps <= 0.0 {
        return 0.0;
    }
    gops_per_s / dsps
}

/// Peak lane-array throughput: 2 ops/MAC * N_i * N_l * fmax.
pub fn peak_gops_per_s(ni: usize, nl: usize, fmax_mhz: f64) -> f64 {
    2.0 * (ni * nl) as f64 * fmax_mhz * 1e6 / 1e9
}

/// Wall-clock speedup of a parallel run over its sequential baseline
/// (the ratio the DSE benches record; ≥ 1 means parallel won).
pub fn speedup(sequential_seconds: f64, parallel_seconds: f64) -> f64 {
    if parallel_seconds <= 0.0 {
        return 0.0;
    }
    sequential_seconds / parallel_seconds
}

/// Evaluation throughput: candidates scored per second (DSE bench axis).
pub fn candidates_per_s(candidates: usize, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    candidates as f64 / seconds
}

/// Latency percentile over a sample of seconds (p in [0, 100]).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(f64::total_cmp);
    let rank = (p / 100.0 * (samples.len() - 1) as f64).round() as usize;
    samples[rank.min(samples.len() - 1)]
}

/// Summary statistics for a latency sample.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    pub n: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl LatencyStats {
    pub fn from_seconds(samples: &[f64]) -> LatencyStats {
        let mut ms: Vec<f64> = samples.iter().map(|s| s * 1e3).collect();
        let n = ms.len();
        if n == 0 {
            return LatencyStats {
                n: 0,
                mean_ms: 0.0,
                p50_ms: 0.0,
                p99_ms: 0.0,
                min_ms: 0.0,
                max_ms: 0.0,
            };
        }
        let mean = ms.iter().sum::<f64>() / n as f64;
        let p50 = percentile(&mut ms, 50.0);
        let p99 = percentile(&mut ms, 99.0);
        LatencyStats {
            n,
            mean_ms: mean,
            p50_ms: p50,
            p99_ms: p99,
            min_ms: ms[0],
            max_ms: ms[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_consistency() {
        // 1.46 GOp in 18.24 ms -> 80.04 GOp/s; 300 DSPs -> 0.266 GOp/s/DSP
        let g = gops_per_s(1.46, 18.24);
        assert!((g - 80.04).abs() < 0.2, "{g}");
        let d = gops_per_dsp(g, 300.0);
        assert!((d - 0.266).abs() < 0.005, "{d}");
    }

    #[test]
    fn paper_table4_consistency() {
        // 31.1 GOp in 205 ms -> 151.7 GOp/s
        let g = gops_per_s(31.1, 205.0);
        assert!((g - 151.7).abs() < 1.0, "{g}");
    }

    #[test]
    fn peak_formula() {
        // (16,32) at 199 MHz: 512 MACs * 2 * 199e6 = 203.8 GOp/s
        let p = peak_gops_per_s(16, 32, 199.0);
        assert!((p - 203.8).abs() < 0.1, "{p}");
    }

    #[test]
    fn percentile_and_stats() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1e3).collect();
        let stats = LatencyStats::from_seconds(&samples);
        assert_eq!(stats.n, 100);
        assert!((stats.p50_ms - 50.0).abs() <= 1.0);
        assert!((stats.p99_ms - 99.0).abs() <= 1.0);
        assert_eq!(stats.min_ms, 1.0);
        assert_eq!(stats.max_ms, 100.0);
    }

    #[test]
    fn degenerate_inputs_are_total() {
        assert_eq!(gops_per_s(1.0, 0.0), 0.0);
        assert_eq!(gops_per_dsp(1.0, 0.0), 0.0);
        assert_eq!(LatencyStats::from_seconds(&[]).n, 0);
        assert_eq!(speedup(1.0, 0.0), 0.0);
        assert_eq!(candidates_per_s(10, 0.0), 0.0);
    }

    #[test]
    fn speedup_and_throughput() {
        assert!((speedup(4.0, 1.0) - 4.0).abs() < 1e-12);
        assert!((speedup(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert!((candidates_per_s(12, 0.5) - 24.0).abs() < 1e-12);
    }
}
